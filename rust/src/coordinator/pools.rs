//! Per-model CPU executor pools with dynamically adjustable core gates.
//!
//! Each model owns an independent FCFS queue (the paper's performance-
//! isolation design). A fixed set of `K_max` worker threads per model is
//! spawned once; at any moment only `k_i` of them may be *active* — the
//! core gate — so reallocation is a single atomic store, not a thread
//! spawn/join (this is what makes <2 ms reconfiguration possible).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of CPU suffix work.
pub struct CpuJob {
    pub model: usize,
    /// Partition point at admission time (suffix = segments [p, P)).
    pub p: usize,
    pub input: Vec<f32>,
    /// Called with the final output on completion.
    pub done: Box<dyn FnOnce(anyhow::Result<Vec<f32>>) + Send>,
}

struct PoolShared {
    queue: Mutex<VecDeque<CpuJob>>,
    cv: Condvar,
    /// Allowed concurrency (k_i) — the core gate.
    allowed: AtomicUsize,
    /// Currently executing workers.
    active: AtomicUsize,
    shutdown: AtomicBool,
}

pub struct CpuPools {
    pools: Vec<Arc<PoolShared>>,
    workers: Vec<JoinHandle<()>>,
}

impl CpuPools {
    /// Spawn `k_max` workers per model. `exec` is invoked inside workers
    /// to run the suffix (it submits to the PJRT executor thread).
    pub fn start<F>(n_models: usize, k_max: usize, exec: F) -> CpuPools
    where
        F: Fn(usize, usize, Vec<f32>) -> anyhow::Result<Vec<f32>> + Send + Sync + 'static,
    {
        let exec = Arc::new(exec);
        let mut pools = Vec::with_capacity(n_models);
        let mut workers = Vec::new();
        for m in 0..n_models {
            let shared = Arc::new(PoolShared {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                allowed: AtomicUsize::new(0),
                active: AtomicUsize::new(0),
                shutdown: AtomicBool::new(false),
            });
            for w in 0..k_max.max(1) {
                let s = shared.clone();
                let exec = exec.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("cpu-pool-{m}-{w}"))
                        .spawn(move || worker_loop(s, exec))
                        .expect("spawn cpu pool worker"),
                );
            }
            pools.push(shared);
        }
        CpuPools { pools, workers }
    }

    pub fn submit(&self, job: CpuJob) {
        let pool = &self.pools[job.model];
        pool.queue.lock().unwrap().push_back(job);
        pool.cv.notify_one();
    }

    /// Apply a new core allocation (the K vector). O(1) per model.
    pub fn set_cores(&self, cores: &[usize]) {
        assert_eq!(cores.len(), self.pools.len());
        for (pool, k) in self.pools.iter().zip(cores) {
            pool.allowed.store(*k, Ordering::SeqCst);
            pool.cv.notify_all();
        }
    }

    pub fn queue_len(&self, model: usize) -> usize {
        self.pools[model].queue.lock().unwrap().len()
    }

    pub fn active(&self, model: usize) -> usize {
        self.pools[model].active.load(Ordering::SeqCst)
    }
}

fn worker_loop<F>(s: Arc<PoolShared>, exec: Arc<F>)
where
    F: Fn(usize, usize, Vec<f32>) -> anyhow::Result<Vec<f32>> + Send + Sync + 'static,
{
    loop {
        let job = {
            let mut q = s.queue.lock().unwrap();
            loop {
                if s.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // Straggler drain: if k dropped to 0 with queued work, one
                // borrowed slot keeps requests from deadlocking (matches
                // the DES's drain rule).
                let allowed = s.allowed.load(Ordering::SeqCst).max(usize::from(!q.is_empty()));
                if !q.is_empty() && s.active.load(Ordering::SeqCst) < allowed {
                    s.active.fetch_add(1, Ordering::SeqCst);
                    break q.pop_front().unwrap();
                }
                q = s.cv.wait(q).unwrap();
            }
        };
        let result = exec(job.model, job.p, job.input);
        (job.done)(result);
        s.active.fetch_sub(1, Ordering::SeqCst);
        s.cv.notify_one();
    }
}

impl Drop for CpuPools {
    fn drop(&mut self) {
        for pool in &self.pools {
            pool.shutdown.store(true, Ordering::SeqCst);
            pool.cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn echo_pools(n: usize, k: usize) -> CpuPools {
        CpuPools::start(n, k, |_m, _p, input| Ok(input))
    }

    #[test]
    fn jobs_complete() {
        let pools = echo_pools(2, 2);
        pools.set_cores(&[1, 1]);
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            let tx = tx.clone();
            pools.submit(CpuJob {
                model: i % 2,
                p: 0,
                input: vec![i as f32],
                done: Box::new(move |r| tx.send(r.unwrap()[0]).unwrap()),
            });
        }
        let mut got: Vec<f32> = (0..10).map(|_| rx.recv().unwrap()).collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, (0..10).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn concurrency_is_gated() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static CUR: AtomicUsize = AtomicUsize::new(0);
        let pools = CpuPools::start(1, 4, |_m, _p, input| {
            let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(c, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            CUR.fetch_sub(1, Ordering::SeqCst);
            Ok(input)
        });
        pools.set_cores(&[2]);
        let (tx, rx) = mpsc::channel();
        for _ in 0..8 {
            let tx = tx.clone();
            pools.submit(CpuJob {
                model: 0,
                p: 0,
                input: vec![0.0],
                done: Box::new(move |_| tx.send(()).unwrap()),
            });
        }
        for _ in 0..8 {
            rx.recv().unwrap();
        }
        assert!(PEAK.load(Ordering::SeqCst) <= 2, "peak={}", PEAK.load(Ordering::SeqCst));
    }

    #[test]
    fn zero_cores_still_drains() {
        let pools = echo_pools(1, 2);
        pools.set_cores(&[0]);
        let (tx, rx) = mpsc::channel();
        pools.submit(CpuJob {
            model: 0,
            p: 0,
            input: vec![7.0],
            done: Box::new(move |r| tx.send(r.unwrap()[0]).unwrap()),
        });
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(2)).unwrap(), 7.0);
    }
}
