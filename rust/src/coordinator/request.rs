//! The request lifecycle: a [`Request`] descriptor goes in, a [`Ticket`]
//! completion handle comes out.
//!
//! This replaces the old fire-hose (`submit` returning a raw `Receiver`)
//! with a first-class lifecycle:
//!
//! * a [`Request`] carries the input, an optional [`SloClass`] override,
//!   an optional **deadline** (relative to submission), and a
//!   [`CancelToken`];
//! * [`Server::submit`](super::Server::submit) resolves admission
//!   synchronously against the station's bounded queue
//!   ([`OverloadPolicy`](crate::sched::OverloadPolicy)) and returns a
//!   [`Ticket`] either way — rejections resolve immediately with the
//!   typed [`RequestError`];
//! * the [`Ticket`] supports blocking [`wait`](Ticket::wait),
//!   non-blocking [`try_wait`](Ticket::try_wait), bounded
//!   [`wait_timeout`](Ticket::wait_timeout), and best-effort
//!   [`cancel`](Ticket::cancel) (a cancelled request that has not started
//!   executing resolves with [`RequestError::Cancelled`]).
//!
//! Every worker exit path delivers a typed error before its sender drops,
//! so a ticket never resolves with an anonymous "server dropped request".

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::analytic::TenantHandle;
use crate::sched::{Overloaded, SloClass};

/// Best-effort cancellation handle shared between a [`Request`], its
/// [`Ticket`], and the workers. Cancelling is a single atomic store;
/// workers check it before starting execution, so a request already on
/// the device still completes normally.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// A request descriptor: what to run, how urgent it is, and how long the
/// caller is willing to wait. `Vec<f32>` converts directly for the common
/// case: `server.submit(h, input)`.
#[derive(Debug, Clone, Default)]
pub struct Request {
    pub input: Vec<f32>,
    /// Override of the tenant's default SLO class for this request.
    pub class: Option<SloClass>,
    /// Completion deadline relative to submission. Under the
    /// `DeadlineDrop` overload policy a request that can no longer meet
    /// it is dropped (typed [`RequestError::DeadlineExceeded`]); under
    /// every policy late completions are excluded from goodput.
    pub deadline: Option<Duration>,
    cancel: CancelToken,
}

impl Request {
    pub fn new(input: Vec<f32>) -> Request {
        Request {
            input,
            ..Request::default()
        }
    }

    pub fn with_class(mut self, class: SloClass) -> Request {
        self.class = Some(class);
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Request {
        self.deadline = Some(deadline);
        self
    }

    /// The token that cancels this request; clone it to cancel from a
    /// different thread than the one holding the [`Ticket`].
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }
}

impl From<Vec<f32>> for Request {
    fn from(input: Vec<f32>) -> Request {
        Request::new(input)
    }
}

/// Why a request did not complete. Every variant is delivered through the
/// [`Ticket`] — the job's real failure is never flattened into a generic
/// channel error.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestError {
    /// The handle was never attached (or already fully detached) at
    /// submission.
    NotAttached(TenantHandle),
    /// The tenant detached while the request was queued.
    Detached(TenantHandle),
    /// Cancelled via its [`CancelToken`] before execution started.
    Cancelled,
    /// The deadline could no longer be met (`DeadlineDrop` eviction, or
    /// already hopeless at submission).
    DeadlineExceeded { deadline_s: f64, now_s: f64 },
    /// A bounded station refused the request (typed backpressure).
    Overloaded(Overloaded),
    /// Evicted from a full queue by a higher-class arrival
    /// (`ShedLowClass`).
    Shed { station: String },
    /// The execution substrate failed.
    Execution(String),
    /// The execution substrate failed *transiently* (a retryable fault):
    /// the worker retried up to its budget with deadline-clipped backoff
    /// and every attempt failed. `attempts` counts executions tried.
    Retryable { reason: String, attempts: u32 },
    /// The server shut down with the request still queued.
    Shutdown,
    /// The completion channel closed without a result (a bug if it ever
    /// surfaces — every worker exit path sends a typed error first).
    ChannelClosed,
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::NotAttached(h) => write!(f, "{h} is not attached"),
            RequestError::Detached(h) => write!(f, "{h} detached before its job ran"),
            RequestError::Cancelled => write!(f, "request cancelled"),
            RequestError::DeadlineExceeded { deadline_s, now_s } => write!(
                f,
                "deadline exceeded: t={deadline_s:.3}s passed at t={now_s:.3}s"
            ),
            RequestError::Overloaded(o) => write!(f, "{o}"),
            RequestError::Shed { station } => {
                write!(f, "shed from {station} by a higher-class request")
            }
            RequestError::Execution(e) => write!(f, "execution failed: {e}"),
            RequestError::Retryable { reason, attempts } => write!(
                f,
                "transient failure persisted after {attempts} attempt(s): {reason}"
            ),
            RequestError::Shutdown => write!(f, "server shut down with the request queued"),
            RequestError::ChannelClosed => write!(f, "completion channel closed"),
        }
    }
}

impl RequestError {
    /// Would resubmitting the same request plausibly succeed? Only the
    /// typed transient-fault variant qualifies; everything else is a
    /// terminal admission, lifecycle, or substrate verdict.
    pub fn is_retryable(&self) -> bool {
        matches!(self, RequestError::Retryable { .. })
    }
}

impl std::error::Error for RequestError {}

/// One finished request.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub tenant: TenantHandle,
    pub latency_s: f64,
    pub output: Vec<f32>,
}

/// Completion handle for one submitted request.
///
/// A resolved ticket caches its result, so `try_wait`/`wait_timeout` can
/// be polled repeatedly and a final `wait` never blocks after resolution.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Completion, RequestError>>,
    cancel: CancelToken,
    tenant: TenantHandle,
    result: Option<Result<Completion, RequestError>>,
}

impl Ticket {
    pub(crate) fn new(
        rx: mpsc::Receiver<Result<Completion, RequestError>>,
        cancel: CancelToken,
        tenant: TenantHandle,
    ) -> Ticket {
        Ticket {
            rx,
            cancel,
            tenant,
            result: None,
        }
    }

    pub fn tenant(&self) -> TenantHandle {
        self.tenant
    }

    /// Request cancellation (best effort — see [`CancelToken`]).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// Block until the request resolves.
    pub fn wait(mut self) -> Result<Completion, RequestError> {
        if let Some(r) = self.result.take() {
            return r;
        }
        self.rx.recv().unwrap_or(Err(RequestError::ChannelClosed))
    }

    /// Non-blocking poll: `None` while the request is still in flight.
    pub fn try_wait(&mut self) -> Option<Result<Completion, RequestError>> {
        if self.result.is_none() {
            match self.rx.try_recv() {
                Ok(r) => self.result = Some(r),
                Err(mpsc::TryRecvError::Empty) => return None,
                Err(mpsc::TryRecvError::Disconnected) => {
                    self.result = Some(Err(RequestError::ChannelClosed));
                }
            }
        }
        self.result.clone()
    }

    /// Block up to `timeout`; `None` means the request is still in
    /// flight (the ticket stays usable).
    pub fn wait_timeout(&mut self, timeout: Duration) -> Option<Result<Completion, RequestError>> {
        if self.result.is_none() {
            match self.rx.recv_timeout(timeout) {
                Ok(r) => self.result = Some(r),
                Err(mpsc::RecvTimeoutError::Timeout) => return None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    self.result = Some(Err(RequestError::ChannelClosed));
                }
            }
        }
        self.result.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resolved(result: Result<Completion, RequestError>) -> Ticket {
        let (tx, rx) = mpsc::channel();
        tx.send(result).unwrap();
        Ticket::new(rx, CancelToken::new(), TenantHandle(0))
    }

    #[test]
    fn ticket_caches_result_across_polls() {
        let mut t = resolved(Err(RequestError::Cancelled));
        assert_eq!(t.try_wait(), Some(Err(RequestError::Cancelled)));
        // Polling again after resolution keeps returning the result.
        assert_eq!(t.try_wait(), Some(Err(RequestError::Cancelled)));
        assert_eq!(
            t.wait_timeout(Duration::from_millis(1)),
            Some(Err(RequestError::Cancelled))
        );
        assert_eq!(t.wait(), Err(RequestError::Cancelled));
    }

    #[test]
    fn ticket_pending_then_closed() {
        let (tx, rx) = mpsc::channel::<Result<Completion, RequestError>>();
        let mut t = Ticket::new(rx, CancelToken::new(), TenantHandle(3));
        assert_eq!(t.tenant(), TenantHandle(3));
        assert_eq!(t.try_wait(), None);
        assert_eq!(t.wait_timeout(Duration::from_millis(1)), None);
        drop(tx);
        assert_eq!(t.wait(), Err(RequestError::ChannelClosed));
    }

    #[test]
    fn request_builder_and_token() {
        let req = Request::new(vec![1.0])
            .with_class(SloClass::Interactive)
            .with_deadline(Duration::from_millis(50));
        assert_eq!(req.class, Some(SloClass::Interactive));
        assert_eq!(req.deadline, Some(Duration::from_millis(50)));
        let token = req.cancel_token();
        assert!(!token.is_cancelled());
        token.cancel();
        assert!(req.cancel_token().is_cancelled());
        let from: Request = vec![2.0].into();
        assert_eq!(from.input, vec![2.0]);
        assert_eq!(from.class, None);
    }
}
