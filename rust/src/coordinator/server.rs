//! The serving front-end: tenant lifecycle, router, TPU worker, policy-
//! driven re-allocator, metrics.
//!
//! The server is built *empty* by a [`ServerBuilder`] (hardware, `K_max`,
//! time scale, reconfiguration policy, exec backend); tenants then come
//! and go at runtime:
//!
//! * [`Server::attach`] performs **model-driven admission control**: the
//!   candidate mix (current tenants + newcomer at its declared rate) is
//!   planned with the analytic model; if no stable configuration exists
//!   (ρ ≥ 1 everywhere the planner can reach) the attach is refused with
//!   a typed [`AdmissionError`] carrying the predicted objective.
//!   Otherwise the server atomically grows the CPU pools, loads the
//!   model's segments through the exec service, extends the prefix-sum
//!   cost tables, and installs the admission plan.
//! * [`Server::detach`] removes a tenant: queued jobs fail cleanly,
//!   in-flight requests complete into the retired stats, and peers keep
//!   their stable [`TenantHandle`]s.
//!
//! Requests are addressed by `TenantHandle` — never by positional index —
//! so statistics and configuration vectors stay correctly keyed across
//! churn. Online re-planning is driven by the *same* [`ReconfigPolicy`]
//! trait the DES uses (`SwapLessPolicy` by default): the policy observes
//! arrivals from the submit path, its `on_attach`/`on_detach` hooks fire
//! at churn, and a periodic thread invokes `decide` — the old hand-rolled
//! `realloc_loop` duplicate of the simulator's policy is gone.
//!
//! Queueing order is likewise shared with the DES: the TPU worker's queue
//! and every tenant's CPU pool run a [`crate::sched`] discipline selected
//! by [`ServerOptions::discipline`] (`--discipline` on the CLI). Tenants
//! declare an [`SloClass`] at attach (overridable per request), and
//! completions are accounted per class in [`ServeStats::per_class`].
//!
//! The request path is a first-class lifecycle ([`super::request`]):
//! [`Server::submit`] takes a [`Request`] (input + class override +
//! deadline + cancellation token) and returns a [`Ticket`]. Every station
//! runs a **bounded admission layer** ([`ServerOptions::queue_capacity`]
//! + [`ServerOptions::overload`], `--queue-cap`/`--overload` on the CLI)
//! through the same [`SchedQueue::offer`] code the DES stations run, so
//! drop behavior validated in simulation holds live: `Reject` refuses
//! work with a typed [`Overloaded`](crate::sched::Overloaded) carrying
//! the O(1) prefix-table wait estimate, `ShedLowClass` evicts the newest
//! lower-class job, and `DeadlineDrop` evicts jobs whose deadline can no
//! longer be met. Per-class accept/drop/goodput counters surface in
//! [`ServeStats`].

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::alloc::{self, AdmissionError};
use crate::analytic::{AnalyticModel, Config, Tenant, TenantHandle};
use crate::config::RuntimeConfig;
use crate::eventlog::{Event as LogEvent, EventKind as LogKind, EventLog};
use crate::fault::{FaultInjector, FaultPlan, Health, RETRY_BACKOFF_S, RETRY_BUDGET};
use crate::metrics::{LatencyHistogram, PerClassLatency};
use crate::model::{Manifest, ModelMeta};
use crate::runtime::service::{ExecBackend, ExecHandle, ExecService};
use crate::sched::{
    DisciplineKind, JobMeta, Offer, OverloadPolicy, RejectReason, SchedQueue, SloClass,
    StationLoad,
};
use crate::sim::reconfig::{ReconfigPolicy, StaticPolicy, SwapLessPolicy};
use crate::telemetry::{
    drift_ratio, emit_burst, ProfiledCostModel, PromWriter, SpanCollector, SpanSampler,
    SpanTrace, Stage, DEFAULT_SPAN_SAMPLE,
};
use crate::tpu::{CostModel, PrefixTables, SramCache};
use crate::util::sync::{lock_or_recover, wait_or_recover, wait_timeout_or_recover};

use super::pools::{CpuJob, CpuPools};
use super::request::{CancelToken, Completion, Request, RequestError, Ticket};

/// Consecutive execution failures before [`Server::health`] reports the
/// device degraded.
const FAIL_STREAK_DEGRADED: u64 = 3;

#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Scale on emulated device-time sleeps (swap/compute budget). 1.0 =
    /// real-time emulation; 0.0 = run as fast as the substrate allows.
    pub time_scale: f64,
    /// Enable the online re-allocator (SwapLess) vs a static config.
    pub adaptive: bool,
    pub runtime: RuntimeConfig,
    pub k_max: usize,
    /// Execution substrate (PJRT artifacts vs manifest-driven emulation).
    pub backend: ExecBackend,
    /// Scheduling discipline for the TPU worker queue and every tenant's
    /// CPU pool — the same `sched` core the DES runs.
    pub discipline: DisciplineKind,
    /// Bound on each station's occupancy (queued + in-service). `None` =
    /// unbounded (the legacy fire-hose). Ignored under
    /// [`OverloadPolicy::Block`].
    pub queue_capacity: Option<usize>,
    /// What a full station does — the same policy set the DES runs
    /// ([`crate::sim::SimOptions::overload`]).
    pub overload: OverloadPolicy,
    /// Index of the TPU device this server instance drives (0 on a
    /// single-device deployment). The fleet router
    /// ([`crate::fleet::FleetServer`]) assigns one per member server and
    /// every job queued here carries it in its [`JobMeta::device`].
    pub device: usize,
    /// Deterministic fault schedule injected into this device's worker
    /// (chaos testing, sim-vs-live parity). `None` = no injected faults.
    /// Plan times are queried for [`device`](Self::device).
    pub faults: Option<Arc<FaultPlan>>,
    /// Wall-clock origin of the fault plan's timeline. The fleet router
    /// passes one shared origin to every member so a single plan replays
    /// consistently across the fleet; `None` = this server's start.
    pub fault_origin: Option<Instant>,
    /// Append every request-lifecycle transition to this event log
    /// (admit/reject/shed/expire/start/complete/cancel). Emission is
    /// off the hot path — see [`crate::eventlog`].
    pub log: Option<EventLog>,
    /// Whether this server closes the log on drop (fsync + torn-tail
    /// truncate). True standalone; the fleet router sets it false on its
    /// members and closes the shared log itself.
    pub log_owned: bool,
    /// Span sampling cadence: every N-th admitted request carries a
    /// stage timeline (emitted as `Span*` records at completion and
    /// folded into the drift estimates behind `GET /metrics`). 0
    /// disables tracing entirely.
    pub span_sample: usize,
    /// Span-calibrated cost model: when set, tenant prefix tables built
    /// at attach use its measured per-prefix overrides instead of pure
    /// analytic values (`--cost profiled` on the CLI).
    pub profile: Option<Arc<ProfiledCostModel>>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            time_scale: 0.0,
            adaptive: true,
            runtime: RuntimeConfig::default(),
            k_max: 4,
            backend: ExecBackend::Auto,
            discipline: DisciplineKind::Fifo,
            queue_capacity: None,
            overload: OverloadPolicy::Block,
            device: 0,
            faults: None,
            fault_origin: None,
            log: None,
            log_owned: true,
            span_sample: DEFAULT_SPAN_SAMPLE,
            profile: None,
        }
    }
}

/// Fluent construction of a [`Server`]. The server starts with zero
/// tenants; use [`Server::attach`] to admit workloads.
pub struct ServerBuilder {
    manifest: Manifest,
    cost: CostModel,
    opts: ServerOptions,
    policy: Option<Box<dyn ReconfigPolicy + Send>>,
}

impl ServerBuilder {
    pub fn new(manifest: &Manifest, cost: CostModel) -> ServerBuilder {
        ServerBuilder {
            manifest: manifest.clone(),
            cost,
            opts: ServerOptions::default(),
            policy: None,
        }
    }

    pub fn time_scale(mut self, v: f64) -> Self {
        self.opts.time_scale = v;
        self
    }

    pub fn adaptive(mut self, on: bool) -> Self {
        self.opts.adaptive = on;
        self
    }

    pub fn k_max(mut self, k: usize) -> Self {
        self.opts.k_max = k;
        self
    }

    pub fn runtime(mut self, rt: RuntimeConfig) -> Self {
        self.opts.runtime = rt;
        self
    }

    pub fn backend(mut self, b: ExecBackend) -> Self {
        self.opts.backend = b;
        self
    }

    /// Select the queueing discipline (default FIFO). A discipline
    /// validated in the DES deploys here unchanged — both paths build
    /// from the same `sched` factory.
    pub fn discipline(mut self, d: DisciplineKind) -> Self {
        self.opts.discipline = d;
        self
    }

    /// Bound every station's occupancy (queued + in-service jobs).
    pub fn queue_capacity(mut self, cap: usize) -> Self {
        self.opts.queue_capacity = Some(cap);
        self
    }

    /// Select the overload policy (default [`OverloadPolicy::Block`],
    /// the legacy unbounded behavior). A policy validated in the DES
    /// deploys here unchanged.
    pub fn overload(mut self, p: OverloadPolicy) -> Self {
        self.opts.overload = p;
        self
    }

    /// Tag this server as device `d` of a multi-device fleet (default 0).
    pub fn device(mut self, d: usize) -> Self {
        self.opts.device = d;
        self
    }

    /// Inject a deterministic fault schedule into this device's worker.
    pub fn faults(mut self, plan: Arc<FaultPlan>) -> Self {
        self.opts.faults = Some(plan);
        self
    }

    /// Anchor the fault plan's `t = 0` at `origin` (shared across a
    /// fleet's members so one plan replays consistently fleet-wide).
    pub fn fault_origin(mut self, origin: Instant) -> Self {
        self.opts.fault_origin = Some(origin);
        self
    }

    /// Append every request-lifecycle transition to `log` (off the hot
    /// path; the log is closed — fsynced, torn tail truncated — when the
    /// server drops).
    pub fn log(mut self, log: EventLog) -> Self {
        self.opts.log = Some(log);
        self
    }

    /// Trace every N-th admitted request with a stage timeline (0
    /// disables; default [`DEFAULT_SPAN_SAMPLE`]).
    pub fn span_sample(mut self, every: usize) -> Self {
        self.opts.span_sample = every;
        self
    }

    /// Build tenant prefix tables from a span-calibrated profiled cost
    /// model instead of the pure analytic one.
    pub fn profile(mut self, pm: Arc<ProfiledCostModel>) -> Self {
        self.opts.profile = Some(pm);
        self
    }

    pub fn options(mut self, opts: ServerOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Install a custom reconfiguration policy (overrides `adaptive`).
    /// The same trait object type drives the DES, so a policy can be
    /// validated in simulation and then deployed live unchanged.
    pub fn policy(mut self, p: Box<dyn ReconfigPolicy + Send>) -> Self {
        self.policy = Some(p);
        self
    }

    pub fn build(self) -> Result<Server> {
        Server::new(self.manifest, self.cost, self.opts, self.policy)
    }
}

/// How `attach` describes the incoming workload to admission control.
#[derive(Debug, Clone)]
pub struct AttachOptions {
    /// Declared/expected arrival rate (requests per second) — the λ the
    /// admission evaluation uses for the newcomer.
    pub rate_hint: f64,
    /// The tenant's default SLO class: tags every request submitted via
    /// [`Server::submit`] (per-request override:
    /// [`Request::with_class`]) and drives priority/WFQ scheduling plus
    /// the per-class latency accounting.
    pub class: SloClass,
}

impl Default for AttachOptions {
    fn default() -> Self {
        AttachOptions {
            rate_hint: 1.0,
            class: SloClass::Standard,
        }
    }
}

/// Why an `attach` failed.
#[derive(Debug)]
pub enum AttachError {
    /// The model is not in the manifest.
    UnknownModel(String),
    /// Admission control refused the mix (no stable configuration); the
    /// payload carries the predicted objective for the best plan found.
    Admission(AdmissionError),
    /// The execution substrate failed to load the model's segments.
    Runtime(anyhow::Error),
}

impl std::fmt::Display for AttachError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AttachError::UnknownModel(e) => write!(f, "unknown model: {e}"),
            AttachError::Admission(e) => write!(f, "{e}"),
            AttachError::Runtime(e) => write!(f, "segment load failed: {e}"),
        }
    }
}

impl std::error::Error for AttachError {}

/// Why a manual `set_config` was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    /// Vector lengths don't match the attached tenant count.
    DimensionMismatch {
        tenants: usize,
        partitions: usize,
        cores: usize,
    },
    /// `partitions[index]` exceeds that model's partition points.
    PartitionOutOfRange {
        index: usize,
        partition: usize,
        max: usize,
    },
    /// The core vector oversubscribes the physical budget.
    CoreBudgetExceeded { total: usize, k_max: usize },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::DimensionMismatch {
                tenants,
                partitions,
                cores,
            } => write!(
                f,
                "config dimension mismatch: {tenants} tenants, {partitions} partitions, \
                 {cores} cores"
            ),
            ConfigError::PartitionOutOfRange {
                index,
                partition,
                max,
            } => write!(f, "partitions[{index}] = {partition} exceeds {max}"),
            ConfigError::CoreBudgetExceeded { total, k_max } => {
                write!(f, "Σk = {total} exceeds K_max = {k_max}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

struct TpuJob {
    handle: TenantHandle,
    meta: Arc<ModelMeta>,
    p: usize,
    class: SloClass,
    /// Predicted CPU-suffix service under the admission-time partition —
    /// precomputed O(1) from the prefix tables at submit, so the worker
    /// never recomputes segment sums when forwarding to a CPU pool.
    cpu_hint: f64,
    /// Absolute deadline (seconds since server start), if any.
    deadline: Option<f64>,
    cancel: CancelToken,
    input: Vec<f32>,
    submitted: Instant,
    done: mpsc::Sender<Result<Completion, RequestError>>,
    /// Sampled stage timeline (None = unsampled). Filled in by the
    /// stations and flushed as one `Span*` burst at completion.
    trace: Option<SpanTrace>,
}

/// A queued TPU job extracted from a crashed device with its completion
/// sender still live, so the fleet router can requeue it on a surviving
/// server without the caller's ticket ever resolving spuriously.
pub(crate) struct FailoverJob {
    pub(crate) class: SloClass,
    /// Absolute deadline on the SOURCE server's clock; the router
    /// translates it before resubmission.
    pub(crate) deadline: Option<f64>,
    pub(crate) cancel: CancelToken,
    pub(crate) input: Vec<f32>,
    pub(crate) submitted: Instant,
    pub(crate) done: mpsc::Sender<Result<Completion, RequestError>>,
}

struct TpuShared {
    /// The worker's queue, ordered by the shared scheduling core.
    queue: Mutex<SchedQueue<TpuJob>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// 1 while the worker is executing a job — the in-service half of
    /// the occupancy bound (queued + in-service <= capacity).
    active: AtomicUsize,
    /// Owner of the job currently executing on the device (`None` when
    /// idle) — makes in-service work visible to [`Server::pending_for`],
    /// so a drain poll cannot report zero while a request of that tenant
    /// still holds the TPU (under `time_scale > 0` or a real backend a
    /// single execution spans many poll intervals).
    active_tenant: Mutex<Option<TenantHandle>>,
    /// Tenants whose SRAM-cache entries must be dropped (detached, or
    /// re-partitioned); drained by the TPU worker before each execution —
    /// the same semantics as the DES's `apply_detach`/`set_config`
    /// invalidation.
    invalidations: Mutex<Vec<TenantHandle>>,
    /// Consecutive failed executions (reset on success) — the error-rate
    /// observer behind [`Server::health`].
    fail_streak: AtomicU64,
}

/// Per-tenant serving statistics, keyed by stable handle. The lifecycle
/// counters follow the shared semantics documented on
/// [`PerClassLatency`]: `accepted` = admitted at the entry station,
/// `rejected` = refused at the entry station by a full queue, `dropped`
/// = everything else the overload layer dropped (shed evictions,
/// deadline drops — at entry or after acceptance — and cancellations).
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub handle: TenantHandle,
    pub name: String,
    pub latency: LatencyHistogram,
    pub accepted: u64,
    pub rejected: u64,
    pub dropped: u64,
    /// True once the tenant detached (its histogram is final).
    pub detached: bool,
}

/// Aggregated serving statistics.
///
/// Drained identities (submissions stopped, every ticket resolved):
/// `submitted == completed + rejected + shed + expired + cancelled +
/// failed`, and `accepted` brackets the post-entry outcomes —
/// `completed + shed <= accepted <= completed + shed + expired +
/// cancelled + failed` (`expired` counts both entry-stage deadline
/// refusals, which were never accepted, and post-acceptance evictions).
/// The conservation property test pins the same identities in the DES.
///
/// Counters are updated outside the queue locks, so a snapshot taken
/// while requests are in flight is *eventually consistent*: a job can be
/// popped and completed (or shed) in the instant before its `accepted`
/// increment lands.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Live tenants first (attach order), then detached tenants.
    pub per_tenant: Vec<TenantStats>,
    /// Latency + lifecycle counters per SLO class (live + detached).
    pub per_class: PerClassLatency,
    pub completed: u64,
    /// Requests that failed cleanly (tenant detached mid-flight, substrate
    /// errors, transient faults that exhausted their retry budget).
    pub failed: u64,
    /// TPU execution attempts (every try, including retries) — with no
    /// injected faults this equals the executions started.
    pub attempted: u64,
    /// Retries after an injected transient fault (bounded per-request
    /// budget, backoff clipped against the deadline).
    pub retried: u64,
    /// Admitted at the entry station.
    pub accepted: u64,
    /// Refused at the entry station by the bounded queue.
    pub rejected: u64,
    /// Evicted post-acceptance by `ShedLowClass` (or refused at a full
    /// internal station mid-pipeline).
    pub shed: u64,
    /// Dropped because the deadline could no longer be met.
    pub expired: u64,
    /// Cancelled via their token before execution.
    pub cancelled: u64,
    pub reconfigs: u64,
    /// Tenants moved onto (or off) this device by the fleet router's
    /// drain-then-move migration — always 0 on a standalone server; the
    /// fleet layer fills it in when aggregating per-device stats.
    pub migrations: u64,
    pub decision_micros: Vec<f64>,
}

impl ServeStats {
    /// The stats row for `h`, live or detached.
    pub fn tenant(&self, h: TenantHandle) -> Option<&TenantStats> {
        self.per_tenant.iter().find(|t| t.handle == h)
    }

    /// Completions that met their deadline (or carried none).
    pub fn goodput(&self) -> u64 {
        self.per_class.goodput_total()
    }

    /// Everything the overload layer dropped (rejected + shed + expired
    /// + cancelled).
    pub fn dropped(&self) -> u64 {
        self.rejected + self.shed + self.expired + self.cancelled
    }
}

struct Entry {
    handle: TenantHandle,
    tenant: Tenant,
    meta: Arc<ModelMeta>,
    /// Default SLO class declared at attach.
    class: SloClass,
    hist: LatencyHistogram,
    accepted: u64,
    rejected: u64,
    dropped: u64,
}

struct State {
    entries: Vec<Entry>,
    config: Config,
    tables: Vec<PrefixTables>,
    /// Bumped on every attach/detach/manual-set so slow policy decisions
    /// against stale snapshots are discarded instead of installed.
    epoch: u64,
}

impl State {
    /// Handle-keyed core gates for `cores` (positionally aligned with
    /// `entries`) — the vector `CpuPools::set_cores` consumes.
    fn gates(&self, cores: &[usize]) -> Vec<(TenantHandle, usize)> {
        self.entries
            .iter()
            .zip(cores)
            .map(|(e, k)| (e.handle, *k))
            .collect()
    }
}

#[derive(Default)]
struct ReconfigLog {
    reconfigs: u64,
    decision_micros: Vec<f64>,
}

// Lock order (outer → inner): `state` → `retired` (detach registers the
// retired row while the entry removal is still invisible) and `state` →
// the pools map (attach grows pools under the state lock); `reconfig`,
// `arrivals`, and `class_hists` are only taken with `state` released
// (`class_hists` is always taken alone). The `policy` lock is NEVER held
// together with `state` (decisions snapshot state, release, then decide)
// nor with `arrivals` (`flush_arrivals` drains the buffer, releases it,
// then locks the policy). Nothing acquires `state` while holding any
// other lock — the order is acyclic.
struct Shared {
    state: Mutex<State>,
    policy: Mutex<Box<dyn ReconfigPolicy + Send>>,
    /// Submit-path arrival observations (time, positional index), buffered
    /// so submitters never contend with the policy lock while `decide`
    /// (a millisecond-scale hill climb) holds it; the policy thread and
    /// the churn paths drain the buffer into `observe_arrival`.
    arrivals: Mutex<Vec<(f64, usize)>>,
    /// False when the policy has no period (static): nothing would ever
    /// drain the buffer, so submits skip it entirely.
    buffer_arrivals: bool,
    retired: Mutex<Vec<TenantStats>>,
    reconfig: Mutex<ReconfigLog>,
    /// Per-SLO-class latency + lifecycle counters across live + retired
    /// tenants.
    class_hists: Mutex<PerClassLatency>,
    completed: AtomicU64,
    failed: AtomicU64,
    attempted: AtomicU64,
    retried: AtomicU64,
    accepted: AtomicU64,
    rejected: AtomicU64,
    shed: AtomicU64,
    expired: AtomicU64,
    cancelled: AtomicU64,
    started: Instant,
    /// Event log shared with every counting path (lock-free emission;
    /// `None` = logging off).
    log: Option<EventLog>,
    /// Fleet device index stamped on every emitted record.
    device: usize,
    /// 1-in-N span sampling decision + id allocation (admission path).
    sampler: SpanSampler,
    /// Lock-free fold of span durations into per-(device, tenant, p,
    /// stage) estimates — the source of the `/metrics` drift gauges and
    /// the live `ProfiledCostModel` calibration.
    collector: Arc<SpanCollector>,
    /// TPU SRAM prefix-cache outcomes (worker-side).
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
}

/// How a request left the system (everything but completion/failure);
/// drives the per-tenant, per-class, and global counters consistently.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Outcome {
    Accept,
    Reject,
    Shed,
    Expired,
    Cancelled,
}

/// Count `outcome` against the tenant's row (live or retired), the
/// per-class counters, and the global counters, and append the matching
/// record to the event log (if one is attached). `entry` marks the
/// request's entry event (admit, or a refusal at the entry station) —
/// what `trace::load_log` reconstructs arrivals from; `deadline` is the
/// absolute deadline the record carries. Lock order: state, then
/// retired, then class_hists — each taken and released in turn.
fn count(
    shared: &Shared,
    handle: TenantHandle,
    class: SloClass,
    outcome: Outcome,
    entry: bool,
    deadline: Option<f64>,
) {
    if let Some(log) = &shared.log {
        let kind = match outcome {
            Outcome::Accept => LogKind::Admit,
            Outcome::Reject => LogKind::Reject,
            Outcome::Shed => LogKind::Shed,
            Outcome::Expired => LogKind::Expire,
            Outcome::Cancelled => LogKind::Cancel,
        };
        let t = shared.started.elapsed().as_secs_f64();
        let mut ev = LogEvent::new(kind, t, shared.device, handle.0, class);
        ev.entry = entry;
        if let Some(d) = deadline {
            ev.value = d;
        }
        log.emit(ev);
    }
    let counted_live = {
        let mut st = lock_or_recover(&shared.state);
        if let Some(e) = st.entries.iter_mut().find(|e| e.handle == handle) {
            match outcome {
                Outcome::Accept => e.accepted += 1,
                Outcome::Reject => e.rejected += 1,
                _ => e.dropped += 1,
            }
            true
        } else {
            false
        }
    };
    if !counted_live {
        let mut retired = lock_or_recover(&shared.retired);
        if let Some(t) = retired.iter_mut().find(|t| t.handle == handle) {
            match outcome {
                Outcome::Accept => t.accepted += 1,
                Outcome::Reject => t.rejected += 1,
                _ => t.dropped += 1,
            }
        }
    }
    let mut pc = lock_or_recover(&shared.class_hists);
    match outcome {
        Outcome::Accept => {
            pc.record_accept(class);
            shared.accepted.fetch_add(1, Ordering::SeqCst);
        }
        Outcome::Reject => {
            pc.record_reject(class);
            shared.rejected.fetch_add(1, Ordering::SeqCst);
        }
        Outcome::Shed => {
            pc.record_shed(class);
            shared.shed.fetch_add(1, Ordering::SeqCst);
        }
        Outcome::Expired => {
            pc.record_expired(class);
            shared.expired.fetch_add(1, Ordering::SeqCst);
        }
        Outcome::Cancelled => {
            pc.record_cancelled(class);
            shared.cancelled.fetch_add(1, Ordering::SeqCst);
        }
    }
}

/// Live multi-tenant inference server with a dynamic tenant set.
pub struct Server {
    // Declaration order matters for Drop: pools joins its workers (which
    // may be blocked on exec replies) before the exec service shuts down.
    pools: Arc<CpuPools>,
    exec: ExecService,
    tpu: Arc<TpuShared>,
    shared: Arc<Shared>,
    manifest: Manifest,
    cost: CostModel,
    am: AnalyticModel,
    k_max: usize,
    discipline: DisciplineKind,
    queue_capacity: Option<usize>,
    overload: OverloadPolicy,
    device: usize,
    injector: Option<FaultInjector>,
    /// Close the event log on drop (standalone servers own their log;
    /// fleet members share the router's and leave closing to it).
    log_owned: bool,
    /// Span-calibrated cost model driving attach-time prefix tables
    /// (`None` = pure analytic).
    profile: Option<Arc<ProfiledCostModel>>,
    next_handle: AtomicU64,
    threads: Vec<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

impl Server {
    fn new(
        manifest: Manifest,
        cost: CostModel,
        opts: ServerOptions,
        policy: Option<Box<dyn ReconfigPolicy + Send>>,
    ) -> Result<Server> {
        let exec = ExecService::start_with_backend(&manifest, &[], opts.backend)?;
        let am = AnalyticModel::new(cost.clone());

        let policy: Box<dyn ReconfigPolicy + Send> = match policy {
            Some(p) => p,
            None if opts.adaptive => Box::new(SwapLessPolicy::new(
                AnalyticModel::new(cost.clone()),
                opts.k_max,
                0,
                opts.runtime.rate_window_s,
                opts.runtime.realloc_period_s,
                opts.runtime.realloc_threshold,
            )),
            None => Box::new(StaticPolicy),
        };
        let has_period = policy.period().is_some();
        let started = Instant::now();

        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                entries: Vec::new(),
                config: Config {
                    partitions: Vec::new(),
                    cores: Vec::new(),
                },
                tables: Vec::new(),
                epoch: 0,
            }),
            policy: Mutex::new(policy),
            arrivals: Mutex::new(Vec::new()),
            buffer_arrivals: has_period,
            retired: Mutex::new(Vec::new()),
            reconfig: Mutex::new(ReconfigLog::default()),
            class_hists: Mutex::new(PerClassLatency::new()),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            attempted: AtomicU64::new(0),
            retried: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            started,
            log: opts.log.clone(),
            device: opts.device,
            sampler: SpanSampler::new(opts.span_sample),
            collector: Arc::new(SpanCollector::new()),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
        });

        // CPU pools execute suffixes through the executor thread; their
        // queues run the same discipline — and the same bounded admission
        // layer — as the TPU worker's.
        let h: ExecHandle = exec.handle();
        let cost_for_pools = cost.clone();
        let scale = opts.time_scale;
        let discipline = opts.discipline;
        let pools = Arc::new(CpuPools::new(
            opts.k_max,
            discipline,
            opts.queue_capacity,
            opts.overload,
            started,
            opts.log.clone(),
            opts.device,
            Some(shared.collector.clone()),
            move |meta, p, input| {
                let t0 = Instant::now();
                let out = h.execute_range(&meta.name, p, meta.partition_points, input)?;
                // Pad to the modeled CPU-suffix budget (virtual device time).
                if scale > 0.0 {
                    let budget = cost_for_pools.cpu_service(meta, p) * scale;
                    let spent = t0.elapsed().as_secs_f64();
                    if budget > spent {
                        std::thread::sleep(Duration::from_secs_f64(budget - spent));
                    }
                }
                Ok(out)
            },
        ));

        // TPU worker thread: sched-core queue + SRAM cache + swap emulation.
        let tpu = Arc::new(TpuShared {
            queue: Mutex::new(SchedQueue::with_kind(discipline)),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            active_tenant: Mutex::new(None),
            invalidations: Mutex::new(Vec::new()),
            fail_streak: AtomicU64::new(0),
        });
        // The fault injector shares the plan's wall-clock origin across a
        // fleet (the router passes one origin to every member), defaulting
        // to this server's own start on a standalone deployment.
        let injector = opts
            .faults
            .clone()
            .map(|plan| FaultInjector::new(plan, opts.device, opts.fault_origin.unwrap_or(started)));
        let mut threads = Vec::new();
        {
            let tpu = tpu.clone();
            let pools = pools.clone();
            let shared = shared.clone();
            let handle = exec.handle();
            let cost = cost.clone();
            let overload = opts.overload;
            let device = opts.device;
            let injector = injector.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("tpu-worker".into())
                    .spawn(move || {
                        tpu_worker_loop(
                            tpu, pools, shared, handle, cost, scale, overload, device, injector,
                        )
                    })?,
            );
        }

        // Policy thread: periodic decide() against live tenant snapshots.
        let stop = Arc::new(AtomicBool::new(false));
        if has_period {
            let shared = shared.clone();
            let pools = pools.clone();
            let stop = stop.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("reconfig-policy".into())
                    .spawn(move || policy_loop(shared, pools, stop))?,
            );
        }

        Ok(Server {
            pools,
            exec,
            tpu,
            shared,
            manifest,
            cost,
            am,
            k_max: opts.k_max,
            discipline,
            queue_capacity: opts.queue_capacity,
            overload: opts.overload,
            device: opts.device,
            injector,
            log_owned: opts.log_owned,
            profile: opts.profile.clone(),
            next_handle: AtomicU64::new(0),
            threads,
            stop,
        })
    }

    /// The scheduling discipline driving the TPU queue and CPU pools.
    pub fn discipline(&self) -> DisciplineKind {
        self.discipline
    }

    /// The overload policy bounding every station's admission.
    pub fn overload(&self) -> OverloadPolicy {
        self.overload
    }

    /// The per-station occupancy bound (`None` = unbounded).
    pub fn queue_capacity(&self) -> Option<usize> {
        self.queue_capacity
    }

    fn now(&self) -> f64 {
        self.shared.started.elapsed().as_secs_f64()
    }

    /// The execution substrate actually in use (`Auto` resolved).
    pub fn backend(&self) -> ExecBackend {
        self.exec.backend()
    }

    /// Admit a tenant: evaluate the candidate mix with the analytic
    /// model (reject with [`AttachError::Admission`] if no stable
    /// configuration exists), then atomically grow the CPU pools, load
    /// the model's segments, extend the prefix tables, and install the
    /// admission plan. Returns the tenant's stable handle.
    pub fn attach(&self, model: &str, opts: AttachOptions) -> Result<TenantHandle, AttachError> {
        let meta = self
            .manifest
            .get(model)
            .map_err(AttachError::UnknownModel)?
            .clone();
        let newcomer = Tenant {
            model: meta.clone(),
            rate: opts.rate_hint,
        };
        // Load segments BEFORE taking the state lock: loading can take
        // seconds on the PJRT backend, is idempotent, and does not depend
        // on the tenant set — holding the lock across it would stall every
        // submit/stats/detach for the duration. A rejected admission below
        // merely leaves the model warm in the executor.
        self.exec.load(model).map_err(AttachError::Runtime)?;

        // Hold the state lock across plan+install so the data plane never
        // observes a half-attached tenant (admission is atomic).
        let mut st = lock_or_recover(&self.shared.state);
        let mut candidate: Vec<Tenant> =
            st.entries.iter().map(|e| e.tenant.clone()).collect();
        candidate.push(newcomer.clone());
        // Extend the standing prefix-table set with the newcomer's table;
        // existing tenants' tables are reused as-is. Handles are
        // allocated under this state lock (concurrent attaches
        // serialize on it), so the pre-read next_handle is exactly the
        // handle this tenant will get — the key the profiled model's
        // span estimates are filed under.
        let next = self.next_handle.load(Ordering::SeqCst);
        let new_table = match &self.profile {
            Some(pm) => pm.tables(self.device, next, &meta),
            None => PrefixTables::new(&self.cost, &meta),
        };
        let mut tables = st.tables.clone();
        tables.push(new_table.clone());
        let plan = alloc::admit_with_tables(&self.am, &candidate, &tables, self.k_max)
            .map_err(AttachError::Admission)?;

        let handle = TenantHandle(self.next_handle.fetch_add(1, Ordering::SeqCst));
        self.pools.add_pool(handle);

        let meta = Arc::new(meta);
        st.tables.push(new_table);
        st.entries.push(Entry {
            handle,
            tenant: newcomer,
            meta,
            class: opts.class,
            hist: LatencyHistogram::default(),
            accepted: 0,
            rejected: 0,
            dropped: 0,
        });
        st.config = plan.config;
        st.epoch += 1;
        let gates = st.gates(&st.config.cores);
        let index = st.entries.len() - 1;
        drop(st);
        self.pools.set_cores(&gates);
        lock_or_recover(&self.shared.reconfig).reconfigs += 1;
        // Deliver arrivals observed under the old tenant set before the
        // hook renumbers positions.
        flush_arrivals(&self.shared);
        lock_or_recover(&self.shared.policy).on_attach(self.now(), index);
        Ok(handle)
    }

    /// Remove a tenant. Its queued CPU/TPU jobs fail cleanly ("detached"),
    /// requests already executing complete into the retired statistics,
    /// and the final histogram is returned. Peers keep their handles.
    pub fn detach(&self, handle: TenantHandle) -> Result<TenantStats> {
        let (index, stats) = {
            let mut st = lock_or_recover(&self.shared.state);
            let Some(i) = st.entries.iter().position(|e| e.handle == handle) else {
                return Err(anyhow::anyhow!("{handle} is not attached"));
            };
            let entry = st.entries.remove(i);
            st.tables.remove(i);
            st.config.partitions.remove(i);
            st.config.cores.remove(i);
            st.epoch += 1;
            let stats = TenantStats {
                handle,
                name: entry.tenant.model.name.clone(),
                latency: entry.hist,
                accepted: entry.accepted,
                rejected: entry.rejected,
                dropped: entry.dropped,
                detached: true,
            };
            // Register the retired stats row while the entry removal is
            // still invisible (state lock held): requests already executing
            // always find one of the two rows — completions are never lost
            // or miskeyed. (Lock order: state → retired.)
            lock_or_recover(&self.shared.retired).push(stats.clone());
            (i, stats)
        };
        // New submits now fail; purge this tenant's queued TPU work
        // through the discipline (peers keep their scheduling state).
        {
            let drained = lock_or_recover(&self.tpu.queue).drain_tenant(handle);
            for (_, job) in drained {
                self.shared.failed.fetch_add(1, Ordering::SeqCst);
                let _ = job.done.send(Err(RequestError::Detached(handle)));
            }
        }
        // Queued CPU jobs fail through their completion callbacks.
        self.pools.remove_pool(handle);
        // Drop the tenant's resident set from the TPU worker's SRAM cache
        // (mirrors the DES's apply_detach invalidation).
        lock_or_recover(&self.tpu.invalidations).push(handle);
        // Deliver arrivals observed under the old tenant set before the
        // hook renumbers positions.
        flush_arrivals(&self.shared);
        lock_or_recover(&self.shared.policy).on_detach(self.now(), index);
        Ok(stats)
    }

    /// Submit a [`Request`] for `handle` and get its [`Ticket`]. The
    /// entry station's bounded admission resolves synchronously: a
    /// refused request's ticket resolves immediately with the typed
    /// [`RequestError`] ([`Overloaded`](RequestError::Overloaded),
    /// [`DeadlineExceeded`](RequestError::DeadlineExceeded), ...), and
    /// unknown/detached handles resolve with
    /// [`NotAttached`](RequestError::NotAttached) — submit itself never
    /// fails. A bare `Vec<f32>` converts into a default `Request`.
    pub fn submit(&self, handle: TenantHandle, request: impl Into<Request>) -> Ticket {
        let request = request.into();
        let cancel = request.cancel_token();
        let (tx, rx) = mpsc::channel();
        let ticket = Ticket::new(rx, cancel.clone(), handle);
        let deadline = request.deadline.map(|d| self.now() + d.as_secs_f64());
        self.submit_inner(
            handle,
            request.class,
            deadline,
            cancel,
            request.input,
            Instant::now(),
            tx,
        );
        ticket
    }

    /// The admission path shared by [`submit`](Self::submit) and the
    /// fleet router's failover requeue
    /// ([`resubmit_failover`](Self::resubmit_failover)). `deadline` is
    /// absolute on this server's clock; `submitted` is preserved across
    /// a requeue so the completion's latency spans the original
    /// submission.
    #[allow(clippy::too_many_arguments)]
    fn submit_inner(
        &self,
        handle: TenantHandle,
        class_override: Option<SloClass>,
        deadline: Option<f64>,
        cancel: CancelToken,
        input: Vec<f32>,
        submitted: Instant,
        tx: mpsc::Sender<Result<Completion, RequestError>>,
    ) {
        let now = self.now();
        let resolved = {
            let st = lock_or_recover(&self.shared.state);
            st.entries.iter().position(|e| e.handle == handle).map(|i| {
                let p = st.config.partitions[i];
                // Scheduling hints from the standing prefix tables — O(1)
                // per submit, bit-exact with the AnalyticModel's
                // service-hint quantities (prop_prefix_tables_bitexact).
                // `hint` orders the first station the request visits;
                // `cpu_hint` rides along for the TPU->CPU forwarding hop.
                let (hint, cpu_hint) = if p > 0 {
                    (st.tables[i].tpu_service(p), st.tables[i].cpu_service(p))
                } else {
                    (st.tables[i].cpu_service(0), 0.0)
                };
                (
                    i,
                    p,
                    st.entries[i].meta.clone(),
                    st.entries[i].class,
                    hint,
                    cpu_hint,
                )
            })
        };
        let Some((index, p, meta, tenant_class, hint, cpu_hint)) = resolved else {
            self.shared.failed.fetch_add(1, Ordering::SeqCst);
            let _ = tx.send(Err(RequestError::NotAttached(handle)));
            return;
        };
        let class = class_override.unwrap_or(tenant_class);
        // Buffered (not observed inline): the policy lock may be held for
        // a whole hill-climb decide; submitters must not wait on it. An
        // arrival flushed after a racing detach renumbered positions is at
        // worst misattributed for one monitor window (the RateMonitor
        // ignores out-of-range indices).
        if self.shared.buffer_arrivals {
            lock_or_recover(&self.shared.arrivals).push((now, index));
        }
        // Sampled BEFORE the admission offer: a refused request emits no
        // spans (dropped timelines would break span conservation), but
        // the sampler's modular counter must tick for every offered
        // request so the cadence stays 1-in-N of offered load.
        let trace = self.shared.sampler.try_begin(p, now);
        if p > 0 {
            let sched_meta = JobMeta {
                tenant: handle,
                class,
                service_hint: hint,
                deadline,
                device: self.device,
            };
            let job = TpuJob {
                handle,
                meta,
                p,
                class,
                cpu_hint,
                deadline,
                cancel,
                input,
                submitted,
                done: tx,
                trace,
            };
            let outcome = {
                let mut q = lock_or_recover(&self.tpu.queue);
                let load = StationLoad {
                    in_service: self.tpu.active.load(Ordering::SeqCst),
                    servers: 1,
                };
                q.offer(
                    sched_meta,
                    job,
                    now,
                    "tpu",
                    self.queue_capacity,
                    self.overload,
                    load,
                )
            };
            match outcome {
                Offer::Admitted { shed, expired } => {
                    count(&self.shared, handle, class, Outcome::Accept, true, deadline);
                    self.tpu.cv.notify_one();
                    self.resolve_tpu_evictions(now, shed, expired);
                }
                Offer::Rejected {
                    meta: m,
                    job,
                    reason,
                    expired,
                } => {
                    self.resolve_tpu_evictions(now, Vec::new(), expired);
                    match reason {
                        RejectReason::Overloaded(o) => {
                            count(&self.shared, handle, class, Outcome::Reject, true, deadline);
                            let _ = job.done.send(Err(RequestError::Overloaded(o)));
                        }
                        RejectReason::Expired => {
                            count(&self.shared, handle, class, Outcome::Expired, true, deadline);
                            let _ = job.done.send(Err(RequestError::DeadlineExceeded {
                                deadline_s: m.deadline.unwrap_or(now),
                                now_s: now,
                            }));
                        }
                    }
                }
            }
        } else {
            dispatch_cpu(
                &self.shared,
                &self.pools,
                handle,
                meta,
                0,
                class,
                hint,
                deadline,
                self.device,
                cancel,
                true,
                input,
                submitted,
                tx,
                trace,
            );
        }
    }

    /// Fail evicted TPU-queue jobs with their typed reasons and count
    /// them (shed victims / deadline drops).
    fn resolve_tpu_evictions(
        &self,
        now: f64,
        shed: Vec<(JobMeta, TpuJob)>,
        expired: Vec<(JobMeta, TpuJob)>,
    ) {
        for (m, j) in shed {
            count(&self.shared, m.tenant, m.class, Outcome::Shed, false, m.deadline);
            let _ = j.done.send(Err(RequestError::Shed {
                station: "tpu".to_string(),
            }));
        }
        for (m, j) in expired {
            count(&self.shared, m.tenant, m.class, Outcome::Expired, false, m.deadline);
            let _ = j.done.send(Err(RequestError::DeadlineExceeded {
                deadline_s: m.deadline.unwrap_or(now),
                now_s: now,
            }));
        }
    }

    pub fn current_config(&self) -> Config {
        lock_or_recover(&self.shared.state).config.clone()
    }

    /// Handles of the currently attached tenants, in attach order
    /// (positionally aligned with [`current_config`](Self::current_config)).
    pub fn handles(&self) -> Vec<TenantHandle> {
        lock_or_recover(&self.shared.state)
            .entries
            .iter()
            .map(|e| e.handle)
            .collect()
    }

    /// The tenant's model metadata (cheap `Arc` clone), if attached.
    pub fn model_meta(&self, handle: TenantHandle) -> Option<Arc<ModelMeta>> {
        lock_or_recover(&self.shared.state)
            .entries
            .iter()
            .find(|e| e.handle == handle)
            .map(|e| e.meta.clone())
    }

    /// Snapshot of the attached tenants (positional order).
    pub fn tenants(&self) -> Vec<Tenant> {
        lock_or_recover(&self.shared.state)
            .entries
            .iter()
            .map(|e| e.tenant.clone())
            .collect()
    }

    /// Manually install a configuration (static baselines/examples).
    /// Validates dimensions against the live tenant count, partition
    /// ranges, and the core budget; counted in `stats().reconfigs` so
    /// baselines and the adaptive path report comparable reconfig stats.
    pub fn set_config(&self, cfg: Config) -> std::result::Result<(), ConfigError> {
        let mut st = lock_or_recover(&self.shared.state);
        let n = st.entries.len();
        if cfg.partitions.len() != n || cfg.cores.len() != n {
            return Err(ConfigError::DimensionMismatch {
                tenants: n,
                partitions: cfg.partitions.len(),
                cores: cfg.cores.len(),
            });
        }
        for (i, e) in st.entries.iter().enumerate() {
            if cfg.partitions[i] > e.meta.partition_points {
                return Err(ConfigError::PartitionOutOfRange {
                    index: i,
                    partition: cfg.partitions[i],
                    max: e.meta.partition_points,
                });
            }
        }
        let total: usize = cfg.cores.iter().sum();
        if total > self.k_max {
            return Err(ConfigError::CoreBudgetExceeded {
                total,
                k_max: self.k_max,
            });
        }
        if cfg != st.config {
            let gates = st.gates(&cfg.cores);
            st.config = cfg;
            st.epoch += 1;
            drop(st);
            self.pools.set_cores(&gates);
            lock_or_recover(&self.shared.reconfig).reconfigs += 1;
        }
        Ok(())
    }

    pub fn stats(&self) -> ServeStats {
        let mut per_tenant: Vec<TenantStats> = {
            let st = lock_or_recover(&self.shared.state);
            st.entries
                .iter()
                .map(|e| TenantStats {
                    handle: e.handle,
                    name: e.tenant.model.name.clone(),
                    latency: e.hist.clone(),
                    accepted: e.accepted,
                    rejected: e.rejected,
                    dropped: e.dropped,
                    detached: false,
                })
                .collect()
        };
        per_tenant.extend(lock_or_recover(&self.shared.retired).iter().cloned());
        let per_class = lock_or_recover(&self.shared.class_hists).clone();
        let log = lock_or_recover(&self.shared.reconfig);
        ServeStats {
            per_tenant,
            per_class,
            completed: self.shared.completed.load(Ordering::SeqCst),
            failed: self.shared.failed.load(Ordering::SeqCst),
            attempted: self.shared.attempted.load(Ordering::SeqCst),
            retried: self.shared.retried.load(Ordering::SeqCst),
            accepted: self.shared.accepted.load(Ordering::SeqCst),
            rejected: self.shared.rejected.load(Ordering::SeqCst),
            shed: self.shared.shed.load(Ordering::SeqCst),
            expired: self.shared.expired.load(Ordering::SeqCst),
            cancelled: self.shared.cancelled.load(Ordering::SeqCst),
            reconfigs: log.reconfigs,
            migrations: 0,
            decision_micros: log.decision_micros.clone(),
        }
    }

    /// The fleet device index this server drives (0 standalone).
    pub fn device(&self) -> usize {
        self.device
    }

    /// Snapshot of the live span-estimate table — the calibration input
    /// of [`ProfiledCostModel::from_estimates`] and the observed side of
    /// the drift gauges.
    pub fn span_estimates(&self) -> crate::telemetry::EstimateMap {
        self.shared.collector.estimates()
    }

    /// The server's span-duration sink (shared with the CPU pools).
    pub fn span_collector(&self) -> Arc<SpanCollector> {
        self.shared.collector.clone()
    }

    /// This server's whole telemetry plane in Prometheus text exposition
    /// format (what `GET /metrics` serves on a standalone deployment).
    pub fn metrics_text(&self) -> String {
        let mut w = PromWriter::new();
        self.render_metrics(&mut w);
        w.finish()
    }

    /// Append this server's metrics to `w`. The fleet router renders
    /// every member into ONE shared writer so HELP/TYPE headers stay
    /// unique across devices (scrapers reject repeated headers).
    pub fn render_metrics(&self, w: &mut PromWriter) {
        let dev = self.device.to_string();
        let stats = self.stats();
        w.header(
            "swapless_requests_total",
            "Request outcomes per tenant",
            "counter",
        );
        for t in &stats.per_tenant {
            let tenant = t.handle.0.to_string();
            for (outcome, v) in [
                ("accepted", t.accepted),
                ("rejected", t.rejected),
                ("dropped", t.dropped),
                ("completed", t.latency.count()),
            ] {
                w.counter(
                    "swapless_requests_total",
                    &[
                        ("device", dev.as_str()),
                        ("tenant", tenant.as_str()),
                        ("model", t.name.as_str()),
                        ("outcome", outcome),
                    ],
                    v,
                );
            }
        }
        w.header(
            "swapless_class_requests_total",
            "Request outcomes per SLO class",
            "counter",
        );
        w.header(
            "swapless_request_latency_seconds",
            "End-to-end latency per SLO class",
            "summary",
        );
        for class in SloClass::ALL {
            let c = class.name();
            for (outcome, v) in [
                ("accepted", stats.per_class.accepted(class)),
                ("rejected", stats.per_class.rejected(class)),
                ("shed", stats.per_class.shed(class)),
                ("expired", stats.per_class.expired(class)),
                ("cancelled", stats.per_class.cancelled(class)),
                ("missed", stats.per_class.missed(class)),
                ("retried", stats.per_class.retried(class)),
            ] {
                w.counter(
                    "swapless_class_requests_total",
                    &[("device", dev.as_str()), ("class", c), ("outcome", outcome)],
                    v,
                );
            }
            w.summary(
                "swapless_request_latency_seconds",
                &[("device", dev.as_str()), ("class", c)],
                stats.per_class.get(class),
            );
        }
        w.header(
            "swapless_server_events_total",
            "Server-level lifecycle totals",
            "counter",
        );
        for (event, v) in [
            ("completed", stats.completed),
            ("failed", stats.failed),
            ("attempted", stats.attempted),
            ("retried", stats.retried),
            ("reconfigs", stats.reconfigs),
        ] {
            w.counter(
                "swapless_server_events_total",
                &[("device", dev.as_str()), ("event", event)],
                v,
            );
        }
        // Station occupancy. The TPU queue's running service-hint sum
        // also feeds the analytic O(1) wait estimate — the prediction
        // the queued-stage drift gauge compares against.
        let (tpu_depth, tpu_queued_service) = {
            let q = lock_or_recover(&self.tpu.queue);
            (q.len(), q.queued_service_s())
        };
        let predicted_wait = self.am.station_wait_estimate(tpu_queued_service, 1);
        let mut cpu_depth = 0usize;
        let mut cpu_active = 0usize;
        for h in self.handles() {
            cpu_depth += self.pools.queue_len(h);
            cpu_active += self.pools.active(h);
        }
        w.header("swapless_queue_depth", "Queued jobs per station", "gauge");
        w.header("swapless_in_service", "Jobs in service per station", "gauge");
        for (station, depth, active) in [
            ("tpu", tpu_depth, self.tpu.active.load(Ordering::SeqCst)),
            ("cpu", cpu_depth, cpu_active),
        ] {
            let labels = [("device", dev.as_str()), ("station", station)];
            w.gauge("swapless_queue_depth", &labels, depth as f64);
            w.gauge("swapless_in_service", &labels, active as f64);
        }
        w.header(
            "swapless_station_wait_estimate_seconds",
            "Analytic O(1) wait estimate for the current TPU backlog",
            "gauge",
        );
        w.gauge(
            "swapless_station_wait_estimate_seconds",
            &[("device", dev.as_str()), ("station", "tpu")],
            predicted_wait,
        );
        w.header(
            "swapless_sram_cache_total",
            "TPU prefix-cache outcomes",
            "counter",
        );
        for (result, v) in [
            ("hit", self.shared.cache_hits.load(Ordering::Relaxed)),
            ("miss", self.shared.cache_misses.load(Ordering::Relaxed)),
        ] {
            w.counter(
                "swapless_sram_cache_total",
                &[("device", dev.as_str()), ("result", result)],
                v,
            );
        }
        if let Some(log) = &self.shared.log {
            w.header(
                "swapless_event_log_records_total",
                "Event-log writer accounting",
                "counter",
            );
            for (state, v) in [("appended", log.appended()), ("dropped", log.dropped())] {
                w.counter(
                    "swapless_event_log_records_total",
                    &[("device", dev.as_str()), ("state", state)],
                    v,
                );
            }
        }
        w.header(
            "swapless_spans_total",
            "Span sampling pipeline accounting",
            "counter",
        );
        for (state, v) in [
            ("offered", self.shared.sampler.offered()),
            ("sampled", self.shared.sampler.sampled()),
            ("overflowed", self.shared.collector.overflowed() as u64),
        ] {
            w.counter(
                "swapless_spans_total",
                &[("device", dev.as_str()), ("state", state)],
                v,
            );
        }
        // Prediction drift: observed span estimates vs the standing
        // prefix-table hints (the exact values the admission path
        // schedules by). Keys of other devices (a fleet-shared
        // collector) and detached tenants are skipped.
        w.header(
            "swapless_observed_stage_seconds",
            "Observed mean stage duration from sampled spans",
            "gauge",
        );
        w.header(
            "swapless_drift_ratio",
            "Observed/predicted service-time drift per stage",
            "gauge",
        );
        let est = self.shared.collector.estimates();
        let st = lock_or_recover(&self.shared.state);
        for ((d, tenant, p), e) in &est {
            if *d as usize != self.device {
                continue;
            }
            let Some(i) = st
                .entries
                .iter()
                .position(|en| en.handle.0 & 0xFFFF_FFFF == *tenant)
            else {
                continue;
            };
            let tables = &st.tables[i];
            let p_us = *p as usize;
            if p_us > tables.partition_points {
                continue;
            }
            let tenant_s = tenant.to_string();
            let p_s = p.to_string();
            for stage in Stage::ALL {
                let Some(s) = e.stage(stage) else { continue };
                let labels = [
                    ("device", dev.as_str()),
                    ("tenant", tenant_s.as_str()),
                    ("p", p_s.as_str()),
                    ("stage", stage.name()),
                ];
                w.gauge("swapless_observed_stage_seconds", &labels, s.estimate());
                let predicted = match stage {
                    Stage::Tpu if p_us > 0 => tables.tpu_service(p_us),
                    Stage::Cpu if p_us < tables.partition_points => tables.cpu_service(p_us),
                    Stage::Swap if p_us > 0 => tables.load_time(p_us),
                    Stage::Queued => predicted_wait,
                    _ => 0.0,
                };
                if let Some(r) = drift_ratio(s.estimate(), predicted) {
                    w.gauge("swapless_drift_ratio", &labels, r);
                }
            }
        }
    }

    /// Work still in the system for `handle`: jobs queued at or
    /// executing on the TPU station, plus jobs queued or executing in
    /// the tenant's CPU pool. (Micro-second handoff windows between
    /// stations can still read zero transiently; callers polling for a
    /// drain should treat two consecutive zero readings as drained.)
    /// The fleet router polls this during drain-then-move migration.
    pub fn pending_for(&self, handle: TenantHandle) -> usize {
        let tpu_queued = lock_or_recover(&self.tpu.queue).count_tenant(handle);
        let tpu_active =
            usize::from(*lock_or_recover(&self.tpu.active_tenant) == Some(handle));
        tpu_queued + tpu_active + self.pools.queue_len(handle) + self.pools.active(handle)
    }

    /// Device health, driven by the injected fault plan (if any) and the
    /// worker's consecutive-execution-failure streak. The fleet router's
    /// health monitor polls this to trigger failover; a plan-driven
    /// `Down` dominates every other signal.
    pub fn health(&self) -> Health {
        if let Some(inj) = &self.injector {
            match inj.health() {
                Health::Up => {}
                h => return h,
            }
        }
        let streak = self.tpu.fail_streak.load(Ordering::SeqCst);
        if streak >= FAIL_STREAK_DEGRADED {
            return Health::Degraded(streak as f64);
        }
        Health::Up
    }

    /// Seconds since this server started — the clock `TpuJob` deadlines
    /// are absolute on. The fleet router uses it to translate deadlines
    /// between member clocks during a failover requeue.
    pub fn now_s(&self) -> f64 {
        self.now()
    }

    /// Extract every queued TPU job of `handle`, completion senders
    /// intact, so a failover can requeue them on a surviving device.
    /// Must run BEFORE `detach`, whose purge resolves queued jobs with
    /// [`RequestError::Detached`]. A job in service on the (possibly
    /// wedged) worker is left to finish there.
    pub(crate) fn drain_for_failover(&self, handle: TenantHandle) -> Vec<FailoverJob> {
        let drained = lock_or_recover(&self.tpu.queue).drain_tenant(handle);
        drained
            .into_iter()
            .map(|(_, j)| FailoverJob {
                class: j.class,
                deadline: j.deadline,
                cancel: j.cancel,
                input: j.input,
                submitted: j.submitted,
                done: j.done,
            })
            .collect()
    }

    /// Requeue a failover-drained job under this server's entry for
    /// `handle`. `deadline` has already been translated onto this
    /// server's clock; the original submission instant rides along so
    /// the eventual completion's latency spans the outage.
    pub(crate) fn resubmit_failover(
        &self,
        handle: TenantHandle,
        job: FailoverJob,
        deadline: Option<f64>,
    ) {
        self.submit_inner(
            handle,
            Some(job.class),
            deadline,
            job.cancel,
            job.input,
            job.submitted,
            job.done,
        );
    }
}

/// Drain buffered submit-path arrivals into the policy's rate monitor.
/// Caller must NOT hold the policy lock.
fn flush_arrivals(shared: &Shared) {
    let batch: Vec<(f64, usize)> =
        std::mem::take(&mut *lock_or_recover(&shared.arrivals));
    if batch.is_empty() {
        return;
    }
    let mut policy = lock_or_recover(&shared.policy);
    for (t, i) in batch {
        policy.observe_arrival(t, i);
    }
}

/// Record a completion against the live entry, or the retired stats if
/// the tenant detached while the request was in flight, plus the
/// per-SLO-class histogram (taken alone — see the lock-order note).
/// `missed` marks a completion delivered after its deadline (kept in the
/// histogram, excluded from goodput).
fn record(shared: &Shared, handle: TenantHandle, class: SloClass, latency: f64, missed: bool) {
    let mut counted = {
        let mut st = lock_or_recover(&shared.state);
        if let Some(e) = st.entries.iter_mut().find(|e| e.handle == handle) {
            e.hist.record(latency);
            true
        } else {
            false
        }
    };
    if !counted {
        let mut retired = lock_or_recover(&shared.retired);
        if let Some(t) = retired.iter_mut().find(|t| t.handle == handle) {
            t.latency.record(latency);
            counted = true;
        }
    }
    if counted {
        if let Some(log) = &shared.log {
            let t = shared.started.elapsed().as_secs_f64();
            let mut ev = LogEvent::new(LogKind::Complete, t, shared.device, handle.0, class);
            ev.missed = missed;
            ev.value = latency;
            log.emit(ev);
        }
        shared.completed.fetch_add(1, Ordering::SeqCst);
        let mut pc = lock_or_recover(&shared.class_hists);
        pc.record(class, latency);
        if missed {
            pc.record_miss(class);
        }
    }
}

/// Classify a typed failure into the lifecycle counters. `entry` = the
/// job was refused at its entry station (an overload refusal there is a
/// `rejected`, mid-pipeline it counts as `shed`). The entry marker on
/// the emitted record follows the same distinction: only entry-station
/// refusals (reject / deadline refusal) are entry events.
fn count_failure(
    shared: &Shared,
    handle: TenantHandle,
    class: SloClass,
    e: &RequestError,
    entry: bool,
    deadline: Option<f64>,
) {
    match e {
        RequestError::Overloaded(_) => count(
            shared,
            handle,
            class,
            if entry { Outcome::Reject } else { Outcome::Shed },
            entry,
            deadline,
        ),
        RequestError::Shed { .. } => {
            count(shared, handle, class, Outcome::Shed, false, deadline)
        }
        RequestError::DeadlineExceeded { .. } => {
            count(shared, handle, class, Outcome::Expired, entry, deadline)
        }
        RequestError::Cancelled => {
            count(shared, handle, class, Outcome::Cancelled, false, deadline)
        }
        _ => {
            shared.failed.fetch_add(1, Ordering::SeqCst);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatch_cpu(
    shared: &Arc<Shared>,
    pools: &Arc<CpuPools>,
    handle: TenantHandle,
    meta: Arc<ModelMeta>,
    p: usize,
    class: SloClass,
    service_hint: f64,
    deadline: Option<f64>,
    device: usize,
    cancel: CancelToken,
    entry: bool,
    input: Vec<f32>,
    submitted: Instant,
    tx: mpsc::Sender<Result<Completion, RequestError>>,
    trace: Option<SpanTrace>,
) {
    let shared2 = shared.clone();
    // Set after a successful offer: lets the completion callback tell a
    // synchronous entry refusal (an entry event on the log) from a
    // post-admission eviction (not one).
    let admitted_flag = Arc::new(AtomicBool::new(false));
    let flag2 = admitted_flag.clone();
    let admitted = pools.submit(
        handle,
        JobMeta {
            tenant: handle,
            class,
            service_hint,
            deadline,
            device,
        },
        CpuJob {
            meta,
            p,
            input,
            cancel,
            trace,
            done: Box::new(move |result| {
                let completion = match result {
                    Ok(output) => {
                        let latency = submitted.elapsed().as_secs_f64();
                        let missed = deadline
                            .map(|d| shared2.started.elapsed().as_secs_f64() > d)
                            .unwrap_or(false);
                        record(&shared2, handle, class, latency, missed);
                        Ok(Completion {
                            tenant: handle,
                            latency_s: latency,
                            output,
                        })
                    }
                    Err(e) => {
                        let at_entry = entry && !flag2.load(Ordering::SeqCst);
                        count_failure(&shared2, handle, class, &e, at_entry, deadline);
                        Err(e)
                    }
                };
                let _ = tx.send(completion);
            }),
        },
    );
    if admitted {
        admitted_flag.store(true, Ordering::SeqCst);
    }
    if entry && admitted {
        count(shared, handle, class, Outcome::Accept, true, deadline);
    }
}

#[allow(clippy::too_many_arguments)]
fn tpu_worker_loop(
    tpu: Arc<TpuShared>,
    pools: Arc<CpuPools>,
    shared: Arc<Shared>,
    handle: ExecHandle,
    cost: CostModel,
    time_scale: f64,
    overload: OverloadPolicy,
    device: usize,
    injector: Option<FaultInjector>,
) {
    let mut cache = SramCache::new(cost.hw.sram_bytes);
    loop {
        let (job, expired) = {
            let mut q = lock_or_recover(&tpu.queue);
            loop {
                if tpu.shutdown.load(Ordering::SeqCst) {
                    // Deliver the typed shutdown error on every queued
                    // job before its sender drops.
                    let rest = q.drain_all();
                    drop(q);
                    for (_, j) in rest {
                        shared.failed.fetch_add(1, Ordering::SeqCst);
                        let _ = j.done.send(Err(RequestError::Shutdown));
                    }
                    return;
                }
                // A crashed (Down) device is unresponsive: it neither
                // pops nor fails queued work, so every queued ticket
                // stays live for the fleet router's failover requeue.
                // Polled waiting doubles as the recovery detector.
                if let Some(inj) = &injector {
                    if inj.is_down() {
                        q = wait_timeout_or_recover(&tpu.cv, q, Duration::from_millis(2));
                        continue;
                    }
                }
                // Deadline-hopeless jobs never reach the device: drained
                // before the pop decision, exactly like the DES's TPU
                // station at service start.
                let mut expired_jobs = Vec::new();
                if overload == OverloadPolicy::DeadlineDrop && !q.is_empty() {
                    let now = shared.started.elapsed().as_secs_f64();
                    expired_jobs = q.drain_expired(now);
                }
                if let Some((_, j)) = q.pop() {
                    tpu.active.store(1, Ordering::SeqCst);
                    break (Some(j), expired_jobs);
                }
                if !expired_jobs.is_empty() {
                    break (None, expired_jobs);
                }
                q = wait_or_recover(&tpu.cv, q);
            }
        };
        if !expired.is_empty() {
            let now = shared.started.elapsed().as_secs_f64();
            for (m, j) in expired {
                count(&shared, m.tenant, m.class, Outcome::Expired, false, m.deadline);
                let _ = j.done.send(Err(RequestError::DeadlineExceeded {
                    deadline_s: m.deadline.unwrap_or(now),
                    now_s: now,
                }));
            }
        }
        let Some(mut job) = job else { continue };
        *lock_or_recover(&tpu.active_tenant) = Some(job.handle);
        // A cancelled request is refused before touching the device.
        if job.cancel.is_cancelled() {
            count(&shared, job.handle, job.class, Outcome::Cancelled, false, job.deadline);
            let _ = job.done.send(Err(RequestError::Cancelled));
            *lock_or_recover(&tpu.active_tenant) = None;
            tpu.active.store(0, Ordering::SeqCst);
            continue;
        }
        // Apply pending invalidations (detached tenants) before touching
        // the cache, so ghost resident sets never pressure live peers.
        for h in lock_or_recover(&tpu.invalidations).drain(..) {
            cache.invalidate(h.0 as usize);
        }
        // Liveness gate: a job that raced a detach (pushed into the queue
        // after the purge ran) is refused here rather than executed — it
        // would otherwise re-insert the detached tenant's weights into the
        // cache and append to a histogram detach() already returned as
        // final. Requests past this gate when their tenant detaches still
        // complete into the retired stats (work already under way); a
        // cache entry re-inserted in that window is removed by the next
        // job's invalidation drain.
        let live = {
            let st = lock_or_recover(&shared.state);
            st.entries.iter().any(|e| e.handle == job.handle)
        };
        if !live {
            shared.failed.fetch_add(1, Ordering::SeqCst);
            let _ = job.done.send(Err(RequestError::Detached(job.handle)));
            *lock_or_recover(&tpu.active_tenant) = None;
            tpu.active.store(0, Ordering::SeqCst);
            continue;
        }
        // Service starts here — past the cancel and liveness gates, about
        // to touch the device (the DES's TPU station emits at the same
        // point in its lifecycle).
        if let Some(log) = &shared.log {
            let t = shared.started.elapsed().as_secs_f64();
            log.emit(LogEvent::new(
                LogKind::Start,
                t,
                device,
                job.handle.0,
                job.class,
            ));
        }
        let meta = job.meta.clone();
        // The queue-wait stage ends here: service is starting.
        let service_start = shared.started.elapsed().as_secs_f64();
        if let Some(tr) = &mut job.trace {
            tr.queued += (service_start - tr.mark).max(0.0);
            tr.mark = service_start;
        }
        let t0 = Instant::now();
        let hit = cache.access(
            job.handle.0 as usize,
            cost.resident_bytes(&meta, job.p),
        );
        if hit {
            shared.cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            shared.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        // Execute with a bounded retry budget against injected transient
        // faults. The backoff doubles per retry and is clipped against
        // the request's absolute deadline: a retry that could not finish
        // in time gives up immediately instead of burning the device.
        // Real substrate errors are terminal (never retried), so the
        // non-injected path is byte-for-byte the old single attempt.
        let mut attempts: u32 = 0;
        let result = loop {
            attempts += 1;
            shared.attempted.fetch_add(1, Ordering::SeqCst);
            let injected = match &injector {
                Some(inj) => inj.next_transient_fails(),
                None => false,
            };
            let attempt = if injected {
                Err(anyhow::anyhow!("injected transient fault"))
            } else {
                handle.execute_range(&meta.name, 0, job.p, job.input.clone())
            };
            match attempt {
                Ok(out) => break Ok(out),
                Err(e) if injected && attempts < RETRY_BUDGET => {
                    let backoff = RETRY_BACKOFF_S * f64::from(1u32 << (attempts - 1));
                    let now = shared.started.elapsed().as_secs_f64();
                    let hopeless = match job.deadline {
                        Some(d) => now + backoff >= d,
                        None => false,
                    };
                    if hopeless {
                        break Err((e, true));
                    }
                    shared.retried.fetch_add(1, Ordering::SeqCst);
                    lock_or_recover(&shared.class_hists).record_retried(job.class);
                    std::thread::sleep(Duration::from_secs_f64(backoff));
                }
                Err(e) => break Err((e, injected)),
            }
        };
        // Enforce the emulated device-time budget (compute + intra swap +
        // optional reload + bus transfers); an active slow-device fault
        // stretches it by its factor (no-op when time_scale = 0).
        if time_scale > 0.0 {
            let mut budget = cost.input_transfer(&meta)
                + cost.tpu_service(&meta, job.p)
                + cost.output_transfer(&meta, job.p);
            if !hit {
                budget += cost.load_time(&meta, job.p);
            }
            let slow = match &injector {
                Some(inj) => inj.slow_factor(),
                None => 1.0,
            };
            let budget = budget * time_scale * slow;
            let spent = t0.elapsed().as_secs_f64();
            if budget > spent {
                std::thread::sleep(Duration::from_secs_f64(budget - spent));
            }
        }
        // Split the device occupancy into swap-in vs pure TPU service
        // for the stage timeline: the swap share is the modeled reload
        // budget actually enforced above (zero on a hit, or when no
        // budget is emulated — then nothing slept on behalf of a swap).
        if let Some(tr) = &mut job.trace {
            let end_s = shared.started.elapsed().as_secs_f64();
            let swap_part = if hit || time_scale <= 0.0 {
                0.0
            } else {
                let slow = match &injector {
                    Some(inj) => inj.slow_factor(),
                    None => 1.0,
                };
                cost.load_time(&meta, job.p) * time_scale * slow
            };
            tr.swap = swap_part;
            tr.tpu = (end_s - tr.mark - swap_part).max(0.0);
            tr.tpu_end = end_s;
            // The CPU-queue wait (if the request forwards) starts now.
            tr.mark = end_s;
        }
        match result {
            Ok(boundary) => {
                tpu.fail_streak.store(0, Ordering::SeqCst);
                if job.p >= meta.partition_points {
                    let latency = job.submitted.elapsed().as_secs_f64();
                    let missed = job
                        .deadline
                        .map(|d| shared.started.elapsed().as_secs_f64() > d)
                        .unwrap_or(false);
                    record(&shared, job.handle, job.class, latency, missed);
                    if let Some(tr) = &job.trace {
                        emit_burst(
                            shared.log.as_ref(),
                            device,
                            job.handle.0,
                            job.class,
                            tr,
                            0.0,
                            tr.tpu_end,
                            meta.partition_points,
                            Some(&shared.collector),
                        );
                    }
                    let _ = job.done.send(Ok(Completion {
                        tenant: job.handle,
                        latency_s: latency,
                        output: boundary,
                    }));
                } else {
                    // Forward to the tenant's CPU pool (fails cleanly if
                    // the tenant detached while we executed the prefix);
                    // the suffix hint was precomputed at submit time.
                    dispatch_cpu(
                        &shared,
                        &pools,
                        job.handle,
                        job.meta,
                        job.p,
                        job.class,
                        job.cpu_hint,
                        job.deadline,
                        device,
                        job.cancel,
                        false,
                        boundary,
                        job.submitted,
                        job.done,
                        job.trace,
                    );
                }
            }
            Err((e, injected)) => {
                tpu.fail_streak.fetch_add(1, Ordering::SeqCst);
                shared.failed.fetch_add(1, Ordering::SeqCst);
                let err = if injected {
                    RequestError::Retryable {
                        reason: e.to_string(),
                        attempts,
                    }
                } else {
                    RequestError::Execution(e.to_string())
                };
                let _ = job.done.send(Err(err));
            }
        }
        *lock_or_recover(&tpu.active_tenant) = None;
        tpu.active.store(0, Ordering::SeqCst);
    }
}

/// The policy thread: sleeps the policy's period (stop-responsive), then
/// snapshots the tenant set, invokes `decide`, and installs the result if
/// the snapshot is still current (epoch check) — attaches/detaches that
/// raced the decision win.
fn policy_loop(shared: Arc<Shared>, pools: Arc<CpuPools>, stop: Arc<AtomicBool>) {
    loop {
        let period = { lock_or_recover(&shared.policy).period() };
        let Some(period) = period else { return };
        let deadline = Instant::now() + Duration::from_secs_f64(period);
        while Instant::now() < deadline {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let now = shared.started.elapsed().as_secs_f64();
        let (tenants, cfg, epoch) = {
            let st = lock_or_recover(&shared.state);
            if st.entries.is_empty() {
                continue;
            }
            (
                st.entries
                    .iter()
                    .map(|e| e.tenant.clone())
                    .collect::<Vec<_>>(),
                st.config.clone(),
                st.epoch,
            )
        };
        flush_arrivals(&shared);
        let t0 = Instant::now();
        let decision = lock_or_recover(&shared.policy).decide(now, &tenants, &cfg);
        let micros = t0.elapsed().as_secs_f64() * 1e6;
        // Every decide invocation is timed — no-change decisions included —
        // so stats().decision_micros is an unbiased sample of the decision
        // path (the <2 ms budget the paper reports).
        lock_or_recover(&shared.reconfig)
            .decision_micros
            .push(micros);
        if let Some(new_cfg) = decision {
            let mut st = lock_or_recover(&shared.state);
            if st.epoch == epoch
                && new_cfg.partitions.len() == st.entries.len()
                && new_cfg != st.config
            {
                let gates = st.gates(&new_cfg.cores);
                st.config = new_cfg;
                st.epoch += 1;
                drop(st);
                pools.set_cores(&gates);
                lock_or_recover(&shared.reconfig).reconfigs += 1;
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.tpu.shutdown.store(true, Ordering::SeqCst);
        self.tpu.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // With the workers joined, flush + fsync the event log and cut
        // any torn tail (the CPU pools drained during field drop only
        // send typed Shutdown errors, which are never logged).
        if self.log_owned {
            if let Some(log) = &self.shared.log {
                log.close();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;

    fn test_server(build: impl FnOnce(ServerBuilder) -> ServerBuilder) -> Server {
        let b = ServerBuilder::new(
            &Manifest::synthetic(),
            CostModel::new(HardwareSpec::default()),
        )
        .backend(ExecBackend::Emulated)
        .adaptive(false);
        build(b).build().unwrap()
    }

    fn input_for(server: &Server, h: TenantHandle) -> Vec<f32> {
        let n: usize = server
            .model_meta(h)
            .expect("attached")
            .input_shape
            .iter()
            .product();
        vec![0.5; n]
    }

    #[test]
    fn poisoned_lock_does_not_cascade_into_the_request_path() {
        let server = test_server(|b| b);
        let h = server
            .attach("mobilenetv2", AttachOptions::default())
            .unwrap();
        // Panic a thread while it holds the state lock. Before the
        // poison-recovering sweep this wedged every later submit, stats,
        // and the worker's completion path.
        let shared = server.shared.clone();
        let _ = std::thread::spawn(move || {
            let _guard = shared.state.lock().unwrap();
            panic!("poison the state lock");
        })
        .join();
        assert!(
            server.shared.state.lock().is_err(),
            "the state lock should be poisoned"
        );
        let done = server.submit(h, input_for(&server, h)).wait().unwrap();
        assert_eq!(done.tenant, h);
        let stats = server.stats();
        assert_eq!(stats.completed, 1);
        assert!(server.detach(h).is_ok());
    }

    #[test]
    fn metrics_text_renders_prometheus_plane_with_drift() {
        let server = test_server(|b| b.span_sample(1));
        let h = server
            .attach("mobilenetv2", AttachOptions::default())
            .unwrap();
        for _ in 0..8 {
            server.submit(h, input_for(&server, h)).wait().unwrap();
        }
        let text = server.metrics_text();
        assert!(text.contains("# HELP swapless_requests_total"));
        assert!(text.contains("# TYPE swapless_requests_total counter"));
        assert!(text.contains("outcome=\"completed\"} 8"));
        assert!(text.contains("swapless_spans_total{device=\"0\",state=\"sampled\"} 8"));
        // Every completed request was traced, so the executed partition
        // has observed stages and at least one drift gauge against the
        // standing prefix-table hints.
        assert!(text.contains("swapless_observed_stage_seconds{"), "{text}");
        assert!(text.contains("swapless_drift_ratio{"), "{text}");
        // Headers are unique and every sample line is well-formed.
        assert_eq!(text.matches("# HELP swapless_requests_total").count(), 1);
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.rsplit_once(' ').is_some(), "malformed: {line}");
        }
    }

    #[test]
    fn transient_faults_exhaust_the_retry_budget_with_a_typed_error() {
        // Probability 1 in an always-active window: every attempt fails,
        // so the request burns the whole budget and resolves Retryable.
        let plan = Arc::new(FaultPlan::new(7).transient(0, 0.0, 1e9, 1.0));
        let server = test_server(|b| b.faults(plan));
        let h = server
            .attach("mobilenetv2", AttachOptions::default())
            .unwrap();
        // Pin an all-TPU split so the request must cross the faulty device.
        server
            .set_config(Config::all_tpu(&server.tenants()))
            .unwrap();
        let err = server.submit(h, input_for(&server, h)).wait().unwrap_err();
        assert!(err.is_retryable());
        match err {
            RequestError::Retryable { attempts, .. } => assert_eq!(attempts, RETRY_BUDGET),
            other => panic!("expected Retryable, got {other:?}"),
        }
        let stats = server.stats();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.attempted, u64::from(RETRY_BUDGET));
        assert_eq!(stats.retried, u64::from(RETRY_BUDGET) - 1);
    }

    #[test]
    fn down_device_parks_queued_jobs_for_failover() {
        // Crashed from t = 0 with no recovery: the worker parks, queued
        // tickets stay unresolved, and the failover drain recovers them
        // with their completion senders intact.
        let plan = Arc::new(FaultPlan::new(1).crash(0, 0.0, None));
        let server = test_server(|b| b.faults(plan));
        let h = server
            .attach("mobilenetv2", AttachOptions::default())
            .unwrap();
        server
            .set_config(Config::all_tpu(&server.tenants()))
            .unwrap();
        assert!(server.health().is_down());
        let mut ticket = server.submit(h, input_for(&server, h));
        assert!(
            ticket.wait_timeout(Duration::from_millis(50)).is_none(),
            "a job on a crashed device must stay in flight, not resolve"
        );
        let jobs = server.drain_for_failover(h);
        assert_eq!(jobs.len(), 1);
        assert_eq!(server.pending_for(h), 0);
        // Dropping the drained job's sender resolves the ticket with the
        // typed channel-closed error — nothing hangs.
        drop(jobs);
        assert_eq!(ticket.wait(), Err(RequestError::ChannelClosed));
    }
}
