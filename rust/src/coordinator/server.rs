//! The serving front-end: router, TPU worker, re-allocator, metrics.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::alloc;
use crate::analytic::{AnalyticModel, Config, Tenant};
use crate::config::RuntimeConfig;
use crate::metrics::LatencyHistogram;
use crate::model::Manifest;
use crate::runtime::service::{ExecHandle, ExecService};
use crate::sim::reconfig::RateMonitor;
use crate::tpu::{CostModel, PrefixTables, SramCache};

use super::pools::{CpuJob, CpuPools};

#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Scale on emulated device-time sleeps (swap/compute budget). 1.0 =
    /// real-time emulation; 0.0 = run as fast as PJRT allows.
    pub time_scale: f64,
    /// Enable the online re-allocator (SwapLess) vs a static config.
    pub adaptive: bool,
    pub runtime: RuntimeConfig,
    pub k_max: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            time_scale: 0.0,
            adaptive: true,
            runtime: RuntimeConfig::default(),
            k_max: 4,
        }
    }
}

/// One finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub model: usize,
    pub latency_s: f64,
    pub output: Vec<f32>,
}

struct TpuJob {
    model: usize,
    p: usize,
    input: Vec<f32>,
    submitted: Instant,
    done: mpsc::Sender<Result<Completion>>,
}

struct TpuShared {
    queue: Mutex<VecDeque<TpuJob>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Aggregated serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub per_model: Vec<LatencyHistogram>,
    pub completed: u64,
    pub reconfigs: u64,
    pub decision_micros: Vec<f64>,
}

struct Shared {
    config: Mutex<Config>,
    stats: Mutex<ServeStats>,
    monitor: Mutex<RateMonitor>,
    started: Instant,
}

/// Live multi-tenant inference server over the AOT artifacts.
pub struct Server {
    _exec: ExecService,
    pools: Arc<CpuPools>,
    tpu: Arc<TpuShared>,
    shared: Arc<Shared>,
    tenants: Vec<Tenant>,
    threads: Vec<JoinHandle<()>>,
    stop_realloc: Arc<AtomicBool>,
}

impl Server {
    pub fn start(
        manifest: &Manifest,
        model_names: &[String],
        cost: CostModel,
        initial: Config,
        opts: ServerOptions,
    ) -> Result<Server> {
        let exec = ExecService::start(manifest, model_names)?;
        let n = model_names.len();
        let tenants: Vec<Tenant> = model_names
            .iter()
            .map(|name| {
                Ok(Tenant {
                    model: manifest.get(name).map_err(|e| anyhow!(e))?.clone(),
                    rate: 0.0,
                })
            })
            .collect::<Result<_>>()?;

        let shared = Arc::new(Shared {
            config: Mutex::new(initial.clone()),
            stats: Mutex::new(ServeStats {
                per_model: (0..n).map(|_| LatencyHistogram::default()).collect(),
                completed: 0,
                reconfigs: 0,
                decision_micros: Vec::new(),
            }),
            monitor: Mutex::new(RateMonitor::new(opts.runtime.rate_window_s, n)),
            started: Instant::now(),
        });

        // CPU pools execute suffixes through the PJRT thread.
        let h: ExecHandle = exec.handle();
        let tenants_for_pools = tenants.clone();
        let cost_for_pools = cost.clone();
        let scale = opts.time_scale;
        let pools = Arc::new(CpuPools::start(n, opts.k_max, move |m, p, input| {
            let meta = &tenants_for_pools[m].model;
            let t0 = Instant::now();
            let out = h.execute_range(&meta.name, p, meta.partition_points, input)?;
            // Pad to the modeled CPU-suffix budget (virtual device time).
            if scale > 0.0 {
                let budget = cost_for_pools.cpu_service(meta, p) * scale;
                let spent = t0.elapsed().as_secs_f64();
                if budget > spent {
                    std::thread::sleep(Duration::from_secs_f64(budget - spent));
                }
            }
            Ok(out)
        }));
        pools.set_cores(&initial.cores);

        // TPU worker thread: FCFS queue + SRAM cache + swap emulation.
        let tpu = Arc::new(TpuShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let mut threads = Vec::new();
        {
            let tpu = tpu.clone();
            let pools = pools.clone();
            let shared = shared.clone();
            let handle = exec.handle();
            let tenants = tenants.clone();
            let cost = cost.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("tpu-worker".into())
                    .spawn(move || {
                        tpu_worker_loop(tpu, pools, shared, handle, tenants, cost, scale)
                    })?,
            );
        }

        // Re-allocator thread.
        let stop_realloc = Arc::new(AtomicBool::new(false));
        if opts.adaptive {
            let shared = shared.clone();
            let pools = pools.clone();
            let tenants = tenants.clone();
            let am = AnalyticModel::new(cost.clone());
            let stop = stop_realloc.clone();
            let rt = opts.runtime.clone();
            let k_max = opts.k_max;
            threads.push(
                std::thread::Builder::new()
                    .name("re-allocator".into())
                    .spawn(move || {
                        realloc_loop(shared, pools, tenants, am, rt, k_max, stop)
                    })?,
            );
        }

        Ok(Server {
            _exec: exec,
            pools,
            tpu,
            shared,
            tenants,
            threads,
            stop_realloc,
        })
    }

    /// Submit a request; the completion arrives on the returned channel.
    pub fn submit(&self, model: usize, input: Vec<f32>) -> mpsc::Receiver<Result<Completion>> {
        let (tx, rx) = mpsc::channel();
        let now = self.shared.started.elapsed().as_secs_f64();
        self.shared.monitor.lock().unwrap().observe(now, model);
        let p = self.shared.config.lock().unwrap().partitions[model];
        if p > 0 {
            let job = TpuJob {
                model,
                p,
                input,
                submitted: Instant::now(),
                done: tx,
            };
            self.tpu.queue.lock().unwrap().push_back(job);
            self.tpu.cv.notify_one();
        } else {
            self.dispatch_cpu(model, 0, input, Instant::now(), tx);
        }
        rx
    }

    /// Blocking single inference (convenience for examples).
    pub fn infer(&self, model: usize, input: Vec<f32>) -> Result<Completion> {
        self.submit(model, input)
            .recv()
            .map_err(|_| anyhow!("server dropped request"))?
    }

    fn dispatch_cpu(
        &self,
        model: usize,
        p: usize,
        input: Vec<f32>,
        submitted: Instant,
        tx: mpsc::Sender<Result<Completion>>,
    ) {
        let shared = self.shared.clone();
        self.pools.submit(CpuJob {
            model,
            p,
            input,
            done: Box::new(move |result| {
                let completion = result.map(|output| {
                    let latency = submitted.elapsed().as_secs_f64();
                    record(&shared, model, latency);
                    Completion {
                        model,
                        latency_s: latency,
                        output,
                    }
                });
                let _ = tx.send(completion);
            }),
        });
    }

    pub fn current_config(&self) -> Config {
        self.shared.config.lock().unwrap().clone()
    }

    /// Manually set a configuration (used by static baselines/examples).
    pub fn set_config(&self, cfg: Config) {
        self.pools.set_cores(&cfg.cores);
        *self.shared.config.lock().unwrap() = cfg;
    }

    pub fn stats(&self) -> ServeStats {
        self.shared.stats.lock().unwrap().clone()
    }

    pub fn tenants(&self) -> &[Tenant] {
        &self.tenants
    }
}

fn record(shared: &Shared, model: usize, latency: f64) {
    let mut stats = shared.stats.lock().unwrap();
    stats.per_model[model].record(latency);
    stats.completed += 1;
}

#[allow(clippy::too_many_arguments)]
fn tpu_worker_loop(
    tpu: Arc<TpuShared>,
    pools: Arc<CpuPools>,
    shared: Arc<Shared>,
    handle: ExecHandle,
    tenants: Vec<Tenant>,
    cost: CostModel,
    time_scale: f64,
) {
    let mut cache = SramCache::new(cost.hw.sram_bytes);
    loop {
        let job = {
            let mut q = tpu.queue.lock().unwrap();
            loop {
                if tpu.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(j) = q.pop_front() {
                    break j;
                }
                q = tpu.cv.wait(q).unwrap();
            }
        };
        let meta = &tenants[job.model].model;
        let t0 = Instant::now();
        let hit = cache.access(job.model, cost.resident_bytes(meta, job.p));
        let result = handle.execute_range(&meta.name, 0, job.p, job.input);
        // Enforce the emulated device-time budget (compute + intra swap +
        // optional reload + bus transfers).
        if time_scale > 0.0 {
            let mut budget = cost.input_transfer(meta)
                + cost.tpu_service(meta, job.p)
                + cost.output_transfer(meta, job.p);
            if !hit {
                budget += cost.load_time(meta, job.p);
            }
            let budget = budget * time_scale;
            let spent = t0.elapsed().as_secs_f64();
            if budget > spent {
                std::thread::sleep(Duration::from_secs_f64(budget - spent));
            }
        }
        match result {
            Ok(boundary) => {
                if job.p >= meta.partition_points {
                    let latency = job.submitted.elapsed().as_secs_f64();
                    record(&shared, job.model, latency);
                    let _ = job.done.send(Ok(Completion {
                        model: job.model,
                        latency_s: latency,
                        output: boundary,
                    }));
                } else {
                    // Forward to the model's CPU pool.
                    let model = job.model;
                    let p = job.p;
                    let submitted = job.submitted;
                    let tx = job.done;
                    let shared2 = shared.clone();
                    pools.submit(CpuJob {
                        model,
                        p,
                        input: boundary,
                        done: Box::new(move |result| {
                            let completion = result.map(|output| {
                                let latency = submitted.elapsed().as_secs_f64();
                                record(&shared2, model, latency);
                                Completion {
                                    model,
                                    latency_s: latency,
                                    output,
                                }
                            });
                            let _ = tx.send(completion);
                        }),
                    });
                }
            }
            Err(e) => {
                let _ = job.done.send(Err(e));
            }
        }
    }
}

fn realloc_loop(
    shared: Arc<Shared>,
    pools: Arc<CpuPools>,
    tenants: Vec<Tenant>,
    am: AnalyticModel,
    rt: RuntimeConfig,
    k_max: usize,
    stop: Arc<AtomicBool>,
) {
    // The served model set is fixed for the life of the server, so the
    // prefix-sum cost tables are built once here and reused by every
    // online decision — each re-plan is then pure O(1)-per-candidate
    // delta evaluation (EXPERIMENTS.md §Perf).
    let tables = PrefixTables::for_tenants(&am.cost, &tenants);
    let mut last_rates: Vec<f64> = vec![0.0; tenants.len()];
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_secs_f64(rt.realloc_period_s));
        let now = shared.started.elapsed().as_secs_f64();
        let rates = shared.monitor.lock().unwrap().rates(now);
        let changed = rates.iter().zip(&last_rates).any(|(n, o)| {
            (n - o).abs() / o.abs().max(0.1) > rt.realloc_threshold
        });
        if !changed {
            continue;
        }
        let t0 = Instant::now();
        let estimated: Vec<Tenant> = tenants
            .iter()
            .zip(&rates)
            .map(|(t, r)| Tenant {
                model: t.model.clone(),
                rate: *r,
            })
            .collect();
        let alloc = alloc::hill_climb_with_tables(&am, &estimated, &tables, k_max);
        let micros = t0.elapsed().as_secs_f64() * 1e6;
        last_rates = rates;
        let mut cfg = shared.config.lock().unwrap();
        let mut stats = shared.stats.lock().unwrap();
        stats.decision_micros.push(micros);
        if *cfg != alloc.config {
            stats.reconfigs += 1;
            pools.set_cores(&alloc.config.cores);
            *cfg = alloc.config;
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_realloc.store(true, Ordering::SeqCst);
        self.tpu.shutdown.store(true, Ordering::SeqCst);
        self.tpu.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}
