//! The online serving coordinator — the L3 request path.
//!
//! Architecture (Fig. 4 of the paper):
//!
//! ```text
//!   clients ──submit()──► router ──► [TPU worker thread]  (FCFS queue,
//!                            │        SRAM cache + swap emulation,
//!                            │        executes prefix via PJRT)
//!                            │              │ boundary tensor
//!                            └──────────────▼
//!                                  [per-model CPU pools]  (k_i-gated
//!                                   workers execute the suffix via PJRT)
//! ```
//!
//! A sliding-window rate monitor feeds the periodic re-allocator, which
//! swaps the shared `Config` (partition points + core allocation) without
//! stopping the pipeline — in-flight requests finish under their
//! admission-time configuration, mirroring the paper's preloaded-partition
//! switching.
//!
//! The Edge TPU itself is emulated: prefix *numerics* run through the real
//! PJRT artifacts, while the device-time budget (compute at MXU speed,
//! swap streams, bus transfers) comes from the shared `CostModel` and is
//! enforced with virtual-time sleeps scaled by `time_scale` (DESIGN.md §3).

pub mod pools;
pub mod server;

pub use pools::CpuPools;
pub use server::{ServeStats, Server, ServerOptions};
