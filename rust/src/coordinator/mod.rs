//! The online serving coordinator — the L3 request path.
//!
//! Architecture (Fig. 4 of the paper, extended with a tenant lifecycle):
//!
//! ```text
//!   attach(model, rate) ──► [admission control]  (analytic model plans the
//!        │                   candidate mix; ρ ≥ 1 everywhere → typed reject)
//!        ▼ TenantHandle
//!   clients ──submit(h, Request)──► [bounded admission]  (queue-cap +
//!        │ Ticket                    OverloadPolicy: reject/shed/deadline)
//!        ▼                              │
//!   wait / try_wait /        router ──► [TPU worker thread]  (sched-core
//!   wait_timeout / cancel       │        queue — FIFO/priority/WFQ/SPSF —
//!                               │        SRAM cache + swap emulation,
//!                               │        executes prefix via the exec service)
//!                               │              │ boundary tensor
//!                               └──────────────▼
//!                                     [per-tenant CPU pools]  (k_i-gated
//!                                      workers, bounded sched-core queues)
//!   detach(h) ──► queued jobs fail with typed errors; stats retire under h
//! ```
//!
//! The tenant set is dynamic: [`Server::attach`] admits a model at runtime
//! (model-driven admission control → grow pools → load segments → install
//! plan) and [`Server::detach`] removes one without disturbing its peers.
//! Requests, statistics, and core gates are keyed by stable
//! [`TenantHandle`](crate::analytic::TenantHandle)s that survive churn.
//!
//! Online re-planning is delegated to the same
//! [`ReconfigPolicy`](crate::sim::reconfig::ReconfigPolicy) trait the DES
//! drives (a `SwapLessPolicy` by default): the submit path feeds its rate
//! monitor, churn fires its `on_attach`/`on_detach` hooks, and a periodic
//! thread invokes `decide` and installs accepted configurations — the
//! in-flight requests finish under their admission-time configuration,
//! mirroring the paper's preloaded-partition switching.
//!
//! Queueing order everywhere on this path is owned by the shared
//! [`crate::sched`] core ([`ServerOptions::discipline`]) — the same
//! trait objects the DES schedules with — and completions are accounted
//! per [`SloClass`](crate::sched::SloClass) in [`ServeStats::per_class`].
//!
//! The Edge TPU itself is emulated: prefix *numerics* run through the
//! exec service (real PJRT artifacts, or the deterministic emulated
//! backend), while the device-time budget (compute at MXU speed, swap
//! streams, bus transfers) comes from the shared `CostModel` and is
//! enforced with virtual-time sleeps scaled by `time_scale` (DESIGN.md §3).

pub mod pools;
pub mod request;
pub mod server;

pub use pools::CpuPools;
pub use request::{CancelToken, Completion, Request, RequestError, Ticket};
pub use server::{
    AttachError, AttachOptions, ConfigError, ServeStats, Server, ServerBuilder, ServerOptions,
    TenantStats,
};
