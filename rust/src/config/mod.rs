//! Configuration system: hardware spec, runtime knobs, and experiment
//! parameters, loadable from JSON files and overridable from the CLI.
//!
//! The defaults model the paper's testbed (Coral USB Edge TPU + Raspberry
//! Pi 5) and are calibrated so the motivation experiments land in the
//! paper's reported ranges (Fig. 1: 20–62% intra-model swap overhead;
//! Fig. 3: early segments several-fold faster on TPU, late segments
//! comparable). See DESIGN.md §3 for the substitution rationale.

use crate::util::json::Json;

/// Hardware + cost-model parameters (Table I's hardware section).
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareSpec {
    /// TPU SRAM capacity `C` in bytes (Edge TPU: 8 MB).
    pub sram_bytes: u64,
    /// Host↔TPU bandwidth `B` in bytes/s (USB 3.0 effective).
    pub bus_bytes_per_sec: f64,
    /// Physical CPU cores `K_max` (Pi 5: quad-core A76).
    pub cpu_cores: usize,
    /// Effective per-core CPU throughput in FLOP/s for int8 CNN inference.
    pub cpu_core_flops: f64,
    /// Peak TPU speedup over one CPU core for a segment that fully fills
    /// the MXU (Fig. 3 calibration: the first segment's advantage).
    pub tpu_speedup_max: f64,
    /// Floor on the TPU/CPU speedup for array-starved segments (late
    /// layers run comparably — the collaborative-processing opportunity).
    pub tpu_speedup_min: f64,
    /// MXU utilization that earns the full `tpu_speedup_max` (global
    /// anchor — models whose kernels underfill the array, e.g. DenseNet's
    /// small growth convs, earn proportionally less; Fig. 1's spread).
    pub mxu_util_anchor: f64,
    /// Fixed per-inference TPU dispatch overhead (driver + USB turnaround).
    pub tpu_dispatch_s: f64,
    /// Fixed per-inference CPU dispatch overhead (thread handoff).
    pub cpu_dispatch_s: f64,
}

impl Default for HardwareSpec {
    fn default() -> Self {
        HardwareSpec {
            sram_bytes: 8 * 1024 * 1024,
            bus_bytes_per_sec: 200e6,
            cpu_cores: 4,
            cpu_core_flops: 25e9,
            tpu_speedup_max: 8.0,
            tpu_speedup_min: 0.7,
            mxu_util_anchor: 0.3,
            tpu_dispatch_s: 1e-3,
            cpu_dispatch_s: 0.5e-3,
        }
    }
}

impl HardwareSpec {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("sram_bytes", Json::Num(self.sram_bytes as f64)),
            ("bus_bytes_per_sec", Json::Num(self.bus_bytes_per_sec)),
            ("cpu_cores", Json::Num(self.cpu_cores as f64)),
            ("cpu_core_flops", Json::Num(self.cpu_core_flops)),
            ("tpu_speedup_max", Json::Num(self.tpu_speedup_max)),
            ("tpu_speedup_min", Json::Num(self.tpu_speedup_min)),
            ("mxu_util_anchor", Json::Num(self.mxu_util_anchor)),
            ("tpu_dispatch_s", Json::Num(self.tpu_dispatch_s)),
            ("cpu_dispatch_s", Json::Num(self.cpu_dispatch_s)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<HardwareSpec, String> {
        let d = HardwareSpec::default();
        let f = |key: &str, dflt: f64| -> f64 {
            j.get(key).and_then(Json::as_f64).unwrap_or(dflt)
        };
        let spec = HardwareSpec {
            sram_bytes: f("sram_bytes", d.sram_bytes as f64) as u64,
            bus_bytes_per_sec: f("bus_bytes_per_sec", d.bus_bytes_per_sec),
            cpu_cores: f("cpu_cores", d.cpu_cores as f64) as usize,
            cpu_core_flops: f("cpu_core_flops", d.cpu_core_flops),
            tpu_speedup_max: f("tpu_speedup_max", d.tpu_speedup_max),
            tpu_speedup_min: f("tpu_speedup_min", d.tpu_speedup_min),
            mxu_util_anchor: f("mxu_util_anchor", d.mxu_util_anchor),
            tpu_dispatch_s: f("tpu_dispatch_s", d.tpu_dispatch_s),
            cpu_dispatch_s: f("cpu_dispatch_s", d.cpu_dispatch_s),
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.sram_bytes == 0 {
            return Err("sram_bytes must be positive".into());
        }
        if self.bus_bytes_per_sec <= 0.0 {
            return Err("bus_bytes_per_sec must be positive".into());
        }
        if self.cpu_cores == 0 {
            return Err("cpu_cores must be positive".into());
        }
        if self.cpu_core_flops <= 0.0 {
            return Err("cpu_core_flops must be positive".into());
        }
        if self.tpu_speedup_max < self.tpu_speedup_min {
            return Err("tpu_speedup_max < tpu_speedup_min".into());
        }
        if self.mxu_util_anchor <= 0.0 {
            return Err("mxu_util_anchor must be positive".into());
        }
        Ok(())
    }

    pub fn load(path: &str) -> Result<HardwareSpec, String> {
        let j = crate::util::json::parse_file(path)?;
        HardwareSpec::from_json(&j)
    }
}

/// Online-coordinator knobs (Section IV's implementation parameters).
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// Sliding-window length for request-rate estimation (seconds).
    pub rate_window_s: f64,
    /// Period between resource-allocation re-evaluations (seconds).
    pub realloc_period_s: f64,
    /// Minimum relative rate change that triggers reconfiguration.
    pub realloc_threshold: f64,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            rate_window_s: 30.0,
            realloc_period_s: 5.0,
            realloc_threshold: 0.05,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        HardwareSpec::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let spec = HardwareSpec::default();
        let j = spec.to_json();
        let back = HardwareSpec::from_json(&j).unwrap();
        assert_eq!(back.sram_bytes, spec.sram_bytes);
        assert_eq!(back.cpu_cores, spec.cpu_cores);
        assert!((back.tpu_speedup_max - spec.tpu_speedup_max).abs() < 1e-12);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = crate::util::json::parse(r#"{"cpu_cores": 8}"#).unwrap();
        let spec = HardwareSpec::from_json(&j).unwrap();
        assert_eq!(spec.cpu_cores, 8);
        assert_eq!(spec.sram_bytes, 8 * 1024 * 1024);
    }

    #[test]
    fn invalid_rejected() {
        let j = crate::util::json::parse(r#"{"cpu_cores": 0}"#).unwrap();
        assert!(HardwareSpec::from_json(&j).is_err());
        let j = crate::util::json::parse(r#"{"bus_bytes_per_sec": -1}"#).unwrap();
        assert!(HardwareSpec::from_json(&j).is_err());
    }
}
