//! Arrival-trace support: record DES/serving arrival streams to JSON and
//! replay externally captured traces (the paper's dynamic scenarios are a
//! special case of piecewise schedules; traces generalize them to
//! arbitrary recorded workloads).
//!
//! Trace format v4 is the binary event log itself
//! ([`crate::eventlog`]): a logged run IS a replayable trace.
//! [`load_log`] filters the log's *entry* records — `Admit`, plus
//! entry-marked `Reject`/`Expire` refusals — and reconstructs the
//! arrival stream they encode (timestamp = arrival instant, tenant
//! handle = model index, deadline carried in the record's value field).
//! [`is_event_log`] sniffs the magic byte so the CLI's `replay` command
//! accepts either format through one path.

use crate::eventlog::{self, EventKind, MAGIC, RECORD_BYTES};
use crate::sched::SloClass;
use crate::util::json::Json;

use super::Arrival;

/// Serialize arrivals to the on-disk trace format (version 3):
/// `{"version":3, "arrivals":[[t, model, class, deadline], ...],
/// "models":[...]}` where `class` is the [`SloClass`] index and
/// `deadline` is the absolute completion deadline (`null` = none).
/// Legacy loads: version-1 traces (two-element `[t, model]` pairs) load
/// as [`SloClass::Standard`] with no deadline; version-2 traces
/// (three-element, classed) load with no deadline.
pub fn to_json(arrivals: &[Arrival], model_names: &[String]) -> Json {
    Json::from_pairs(vec![
        ("version", Json::Num(3.0)),
        (
            "models",
            Json::Arr(model_names.iter().map(|n| Json::Str(n.clone())).collect()),
        ),
        (
            "arrivals",
            Json::Arr(
                arrivals
                    .iter()
                    .map(|a| {
                        Json::Arr(vec![
                            Json::Num(a.time),
                            Json::Num(a.model as f64),
                            Json::Num(a.class.index() as f64),
                            match a.deadline {
                                Some(d) => Json::Num(d),
                                None => Json::Null,
                            },
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

pub fn from_json(j: &Json) -> Result<(Vec<Arrival>, Vec<String>), String> {
    let models: Vec<String> = j
        .arr_of("models")
        .map_err(|e| e.to_string())?
        .iter()
        .filter_map(|v| v.as_str().map(str::to_string))
        .collect();
    let mut arrivals = Vec::new();
    let mut last_t = f64::NEG_INFINITY;
    for (i, pair) in j
        .arr_of("arrivals")
        .map_err(|e| e.to_string())?
        .iter()
        .enumerate()
    {
        let a = pair
            .as_arr()
            .filter(|a| (2..=4).contains(&a.len()))
            .ok_or_else(|| format!("arrival {i} is not a [t, model(, class(, deadline))] entry"))?;
        let time = a[0]
            .as_f64()
            .ok_or_else(|| format!("arrival {i}: bad time"))?;
        let model = a[1]
            .as_usize()
            .ok_or_else(|| format!("arrival {i}: bad model index"))?;
        let class = match a.get(2) {
            None => SloClass::Standard,
            Some(c) => c
                .as_usize()
                .and_then(SloClass::from_index)
                .ok_or_else(|| format!("arrival {i}: bad SLO class"))?,
        };
        let deadline = match a.get(3) {
            None | Some(Json::Null) => None,
            Some(d) => {
                let d = d
                    .as_f64()
                    .filter(|d| d.is_finite() && *d >= 0.0)
                    .ok_or_else(|| format!("arrival {i}: bad deadline"))?;
                Some(d)
            }
        };
        if model >= models.len() {
            return Err(format!("arrival {i}: model {model} out of range"));
        }
        if time < last_t {
            return Err(format!("arrival {i}: trace not time-sorted"));
        }
        if !time.is_finite() || time < 0.0 {
            return Err(format!("arrival {i}: invalid time {time}"));
        }
        last_t = time;
        arrivals.push(Arrival {
            time,
            model,
            class,
            deadline,
        });
    }
    Ok((arrivals, models))
}

pub fn save(path: &str, arrivals: &[Arrival], model_names: &[String]) -> Result<(), String> {
    crate::util::json::write_file(path, &to_json(arrivals, model_names))
}

pub fn load(path: &str) -> Result<(Vec<Arrival>, Vec<String>), String> {
    let j = crate::util::json::parse_file(path)?;
    from_json(&j)
}

/// Sniff whether `path` is a binary event log (trace format v4): at
/// least one whole record, the magic byte in place, a valid kind.
pub fn is_event_log(path: &str) -> bool {
    use std::io::Read;
    let Ok(mut f) = std::fs::File::open(path) else {
        return false;
    };
    let mut buf = [0u8; RECORD_BYTES];
    if f.read_exact(&mut buf).is_err() {
        return false;
    }
    buf[3] == MAGIC && buf[0] < EventKind::ALL.len() as u8
}

/// Load the arrival stream recorded in a binary event log (trace format
/// v4): the entry-marked records (`Admit`, plus entry refusals) map
/// one-to-one onto the run's post-warmup arrivals — timestamp is the
/// arrival instant and the value field carries the deadline. Tenant
/// handles are ambiguous on their own: member servers in a fleet number
/// handles from 0 *per device* (the same collision
/// `eventlog::views::Rollup` keys `per_tenant` by `(device, handle)`
/// for), so the model identity is the `(device, handle)` pair, densely
/// renumbered in `(device, handle)` order. A single-device log with
/// contiguous handles keeps `model == handle` (attach order); a
/// multi-device log orders models by device first, then handle. Returns
/// the arrivals (stably re-sorted by time: per-device writer order
/// interleaves across devices) and the distinct tenant count.
pub fn load_log(path: &str) -> Result<(Vec<Arrival>, usize), String> {
    let events = eventlog::read_all(path)?;
    let entries: Vec<&eventlog::Event> = events.iter().filter(|e| e.entry).collect();
    if entries.is_empty() {
        return Err(format!(
            "{path}: no entry records — not a logged workload (or logging began mid-run)"
        ));
    }
    let mut keys: Vec<(u16, u64)> = entries.iter().map(|e| (e.device, e.tenant)).collect();
    keys.sort_unstable();
    keys.dedup();
    let index: std::collections::BTreeMap<(u16, u64), usize> =
        keys.iter().copied().zip(0..).collect();
    let mut arrivals: Vec<Arrival> = entries
        .iter()
        .map(|e| Arrival {
            time: e.t,
            model: index[&(e.device, e.tenant)],
            class: e.class,
            deadline: e.deadline(),
        })
        .collect();
    arrivals.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap());
    Ok((arrivals, keys.len()))
}

/// Empirical per-model rates over a trace (for planning from a recording).
pub fn empirical_rates(arrivals: &[Arrival], n_models: usize, horizon: f64) -> Vec<f64> {
    let mut counts = vec![0usize; n_models];
    for a in arrivals {
        counts[a.model] += 1;
    }
    counts
        .iter()
        .map(|c| *c as f64 / horizon.max(1e-9))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::{generate_arrivals, RateSchedule};

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let arr = generate_arrivals(
            &[RateSchedule::constant(3.0), RateSchedule::constant(1.0)],
            50.0,
            &mut rng,
        );
        let names = vec!["a".to_string(), "b".to_string()];
        let j = to_json(&arr, &names);
        let (back, back_names) = from_json(&j).unwrap();
        assert_eq!(back_names, names);
        assert_eq!(back.len(), arr.len());
        assert_eq!(back[0], arr[0]);
        assert_eq!(back[back.len() - 1], arr[arr.len() - 1]);
    }

    #[test]
    fn classed_roundtrip_and_legacy_load() {
        let arr = vec![
            Arrival {
                time: 0.5,
                model: 0,
                class: SloClass::Interactive,
                deadline: Some(0.55),
            },
            Arrival {
                time: 1.5,
                model: 1,
                class: SloClass::Batch,
                deadline: None,
            },
        ];
        let names = vec!["a".to_string(), "b".to_string()];
        let j = to_json(&arr, &names);
        assert_eq!(j.f64_of("version").unwrap(), 3.0);
        let (back, _) = from_json(&j).unwrap();
        assert_eq!(back, arr);
        // Version-1 two-element entries default to Standard, no deadline.
        let legacy = crate::util::json::parse(
            r#"{"version":1,"models":["a"],"arrivals":[[1.0, 0]]}"#,
        )
        .unwrap();
        let (back, _) = from_json(&legacy).unwrap();
        assert_eq!(back[0].class, SloClass::Standard);
        assert_eq!(back[0].deadline, None);
        // Version-2 three-element entries load with no deadline.
        let v2 = crate::util::json::parse(
            r#"{"version":2,"models":["a"],"arrivals":[[1.0, 0, 2]]}"#,
        )
        .unwrap();
        let (back, _) = from_json(&v2).unwrap();
        assert_eq!(back[0].class, SloClass::Batch);
        assert_eq!(back[0].deadline, None);
        // Out-of-range class index is rejected.
        let bad = crate::util::json::parse(
            r#"{"version":2,"models":["a"],"arrivals":[[1.0, 0, 9]]}"#,
        )
        .unwrap();
        assert!(from_json(&bad).is_err());
        // Negative/non-finite deadlines are rejected; null loads as None.
        let bad = crate::util::json::parse(
            r#"{"version":3,"models":["a"],"arrivals":[[1.0, 0, 0, -2.0]]}"#,
        )
        .unwrap();
        assert!(from_json(&bad).is_err());
        let ok = crate::util::json::parse(
            r#"{"version":3,"models":["a"],"arrivals":[[1.0, 0, 0, null]]}"#,
        )
        .unwrap();
        let (back, _) = from_json(&ok).unwrap();
        assert_eq!(back[0].deadline, None);
    }

    #[test]
    fn rejects_malformed() {
        let bad = crate::util::json::parse(
            r#"{"version":1,"models":["a"],"arrivals":[[1.0, 5]]}"#,
        )
        .unwrap();
        assert!(from_json(&bad).is_err()); // model out of range
        let bad = crate::util::json::parse(
            r#"{"version":1,"models":["a"],"arrivals":[[2.0, 0],[1.0, 0]]}"#,
        )
        .unwrap();
        assert!(from_json(&bad).is_err()); // unsorted
        let bad = crate::util::json::parse(
            r#"{"version":1,"models":["a"],"arrivals":[[-1.0, 0]]}"#,
        )
        .unwrap();
        assert!(from_json(&bad).is_err()); // negative time
    }

    #[test]
    fn binary_log_sniff_and_arrival_extraction() {
        use crate::eventlog::{Event, EventKind, EventLog};
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        // A JSON trace is not an event log.
        let jpath = dir.join(format!("swapless-trace-sniff-{pid}.json"));
        let jpath = jpath.to_str().unwrap().to_string();
        let arr = vec![Arrival {
            time: 1.0,
            model: 0,
            class: SloClass::Standard,
            deadline: None,
        }];
        save(&jpath, &arr, &["a".to_string()]).unwrap();
        assert!(!is_event_log(&jpath));
        assert!(!is_event_log("/nonexistent/trace.log"));
        // A written log is, and its entry records load as arrivals.
        let lpath = dir.join(format!("swapless-trace-sniff-{pid}.log"));
        let lpath = lpath.to_str().unwrap().to_string();
        let log = EventLog::create(&lpath).unwrap();
        let mut admit = Event::new(EventKind::Admit, 0.25, 0, 1, SloClass::Interactive);
        admit.entry = true;
        admit.value = 0.75; // deadline
        log.emit(admit);
        let mut reject = Event::new(EventKind::Reject, 0.125, 1, 0, SloClass::Batch);
        reject.entry = true;
        log.emit(reject);
        // Non-entry records are not arrivals.
        log.emit(Event::new(EventKind::Complete, 0.5, 0, 1, SloClass::Interactive));
        log.close();
        assert!(is_event_log(&lpath));
        let (back, n_models) = load_log(&lpath).unwrap();
        assert_eq!(n_models, 2);
        assert_eq!(back.len(), 2);
        // Re-sorted by time across devices; models are dense indices in
        // (device, handle) order: (0,1) -> 0, (1,0) -> 1.
        assert_eq!(back[0].time, 0.125);
        assert_eq!(back[0].model, 1);
        assert_eq!(back[0].deadline, None);
        assert_eq!(back[1].model, 0);
        assert_eq!(back[1].class, SloClass::Interactive);
        assert_eq!(back[1].deadline, Some(0.75));
        let _ = std::fs::remove_file(&jpath);
        let _ = std::fs::remove_file(&lpath);
    }

    #[test]
    fn load_log_keeps_same_handle_on_different_devices_distinct() {
        use crate::eventlog::{Event, EventKind, EventLog};
        let path = std::env::temp_dir().join(format!(
            "swapless-trace-collide-{}.log",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        // Member servers number handles from 0 per device: handle 0 on
        // device 0 and handle 0 on device 1 are different tenants.
        let log = EventLog::create(&path).unwrap();
        for (t, device) in [(0.1, 0), (0.2, 1), (0.3, 0), (0.4, 1)] {
            let mut ev = Event::new(EventKind::Admit, t, device, 0, SloClass::Standard);
            ev.entry = true;
            log.emit(ev);
        }
        log.close();
        let (back, n_models) = load_log(&path).unwrap();
        assert_eq!(n_models, 2, "same handle on two devices = two tenants");
        assert_eq!(
            back.iter().map(|a| a.model).collect::<Vec<_>>(),
            vec![0, 1, 0, 1]
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empirical_rates_match_generation() {
        let mut rng = Rng::new(9);
        let arr = generate_arrivals(
            &[RateSchedule::constant(4.0), RateSchedule::constant(2.0)],
            500.0,
            &mut rng,
        );
        let rates = empirical_rates(&arr, 2, 500.0);
        assert!((rates[0] - 4.0).abs() < 0.4);
        assert!((rates[1] - 2.0).abs() < 0.3);
    }
}
