//! Workload generation: Poisson arrival streams, ρ-targeted rate solving,
//! mix construction, and the time-varying traces of Fig. 8.

pub mod trace;

use crate::analytic::{AnalyticModel, Config, Tenant};
use crate::sched::SloClass;
use crate::util::rng::Rng;

/// A request arrival: (time, model index, SLO class, optional deadline).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub time: f64,
    pub model: usize,
    /// The SLO class the request is tagged with (threaded through the
    /// DES into the shared scheduling core and per-class accounting).
    pub class: SloClass,
    /// Absolute completion deadline (same clock as `time`); `None` = no
    /// deadline. The `DeadlineDrop` overload policy acts on it; every
    /// policy accounts goodput against it.
    pub deadline: Option<f64>,
}

/// A piecewise-constant rate schedule for one model: (start_time, rate).
/// Rates hold until the next breakpoint (Fig. 8 uses steps at 300 s/600 s).
///
/// Steps are kept sorted by start time — [`rate_at`](Self::rate_at) scans
/// with an early exit, which returns wrong rates on unsorted input, so
/// the field is private and every constructor establishes the order.
#[derive(Debug, Clone)]
pub struct RateSchedule {
    steps: Vec<(f64, f64)>,
}

impl RateSchedule {
    pub fn constant(rate: f64) -> RateSchedule {
        RateSchedule {
            steps: vec![(0.0, rate)],
        }
    }

    /// Build a stepped schedule from `(start_time, rate)` breakpoints.
    /// The steps are sorted by start time (stable, so among equal starts
    /// the later entry wins, matching `rate_at`'s last-match semantics);
    /// non-finite times/rates and negative rates are rejected.
    pub fn stepped(mut steps: Vec<(f64, f64)>) -> RateSchedule {
        for (t, r) in &steps {
            assert!(
                t.is_finite() && r.is_finite() && *r >= 0.0,
                "bad rate step ({t}, {r})"
            );
        }
        steps.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        RateSchedule { steps }
    }

    pub fn steps(&self) -> &[(f64, f64)] {
        &self.steps
    }

    pub fn rate_at(&self, t: f64) -> f64 {
        let mut rate = 0.0;
        for (start, r) in &self.steps {
            if t >= *start {
                rate = *r;
            } else {
                break;
            }
        }
        rate
    }

    /// Stepped approximation of a diurnal load curve:
    /// `base · (1 + amplitude · sin(2π t / period))`, sampled at the
    /// center of `steps_per_period` windows per period over `[0,
    /// horizon)` and clamped at zero. The scenario suite's "daily" load
    /// shape (arXiv 2201.07312 §workloads).
    pub fn diurnal(
        base: f64,
        amplitude: f64,
        period: f64,
        steps_per_period: usize,
        horizon: f64,
    ) -> RateSchedule {
        assert!(base >= 0.0 && period > 0.0 && steps_per_period > 0);
        let dt = period / steps_per_period as f64;
        let mut steps = Vec::new();
        let mut t = 0.0;
        while t < horizon {
            let mid = t + 0.5 * dt;
            let r = base * (1.0 + amplitude * (2.0 * std::f64::consts::PI * mid / period).sin());
            steps.push((t, r.max(0.0)));
            t += dt;
        }
        RateSchedule::stepped(steps)
    }

    /// A flash crowd: `base` everywhere except `[from, until)`, where the
    /// rate jumps to `spike`.
    pub fn flash_crowd(base: f64, spike: f64, from: f64, until: f64) -> RateSchedule {
        assert!(from < until, "flash crowd window is empty");
        RateSchedule::stepped(vec![(0.0, base), (from, spike), (until, base)])
    }
}

/// Popularity-drift schedules: the total request rate stays `total`, but
/// the per-model split linearly interpolates from `from_weights` to
/// `to_weights` over `[0, horizon)` in `steps` piecewise-constant
/// segments (weights are normalized internally). Returns one schedule
/// per model, positionally aligned with the weight slices.
pub fn drift_schedules(
    total: f64,
    from_weights: &[f64],
    to_weights: &[f64],
    horizon: f64,
    steps: usize,
) -> Vec<RateSchedule> {
    assert_eq!(from_weights.len(), to_weights.len());
    assert!(steps > 0 && horizon > 0.0 && total >= 0.0);
    let norm = |w: &[f64]| -> Vec<f64> {
        let s: f64 = w.iter().sum();
        assert!(s > 0.0, "weights sum to zero");
        w.iter().map(|x| x / s).collect()
    };
    let from = norm(from_weights);
    let to = norm(to_weights);
    let dt = horizon / steps as f64;
    (0..from.len())
        .map(|m| {
            let steps_m: Vec<(f64, f64)> = (0..steps)
                .map(|k| {
                    // Fraction at the segment center: step 0 leans on
                    // `from`, the last step on `to`.
                    let frac = (k as f64 + 0.5) / steps as f64;
                    let w = from[m] + (to[m] - from[m]) * frac;
                    (k as f64 * dt, total * w)
                })
                .collect();
            RateSchedule::stepped(steps_m)
        })
        .collect()
}

/// Generate a merged Poisson arrival stream for `schedules` over
/// [0, horizon), every arrival tagged [`SloClass::Standard`].
pub fn generate_arrivals(
    schedules: &[RateSchedule],
    horizon: f64,
    rng: &mut Rng,
) -> Vec<Arrival> {
    let classes = vec![SloClass::Standard; schedules.len()];
    generate_arrivals_classed(schedules, &classes, horizon, rng)
}

/// Generate a merged Poisson arrival stream with one SLO class per model
/// (`classes` is positionally aligned with `schedules`).
pub fn generate_arrivals_classed(
    schedules: &[RateSchedule],
    classes: &[SloClass],
    horizon: f64,
    rng: &mut Rng,
) -> Vec<Arrival> {
    let deadlines = vec![None; schedules.len()];
    generate_arrivals_annotated(schedules, classes, &deadlines, horizon, rng)
}

/// Generate a merged Poisson arrival stream with one SLO class and one
/// optional *relative* deadline per model (both positionally aligned with
/// `schedules`); each arrival's absolute deadline is its arrival time
/// plus the model's relative deadline.
///
/// Uses thinning against each model's max rate, so rate steps are honored
/// exactly (not just at event boundaries). The RNG consumption is
/// independent of the annotations, so the same seed yields the same
/// arrival times with or without deadlines.
pub fn generate_arrivals_annotated(
    schedules: &[RateSchedule],
    classes: &[SloClass],
    deadlines: &[Option<f64>],
    horizon: f64,
    rng: &mut Rng,
) -> Vec<Arrival> {
    assert_eq!(schedules.len(), classes.len());
    assert_eq!(schedules.len(), deadlines.len());
    let mut all = Vec::new();
    for (m, sched) in schedules.iter().enumerate() {
        let max_rate = sched
            .steps
            .iter()
            .map(|(_, r)| *r)
            .fold(0.0f64, f64::max);
        if max_rate <= 0.0 {
            continue;
        }
        let mut t = 0.0;
        let mut r = rng.fork(m as u64 + 1);
        loop {
            t += r.exponential(max_rate);
            if t >= horizon {
                break;
            }
            // thinning: accept with prob rate(t)/max_rate
            if r.f64() < sched.rate_at(t) / max_rate {
                all.push(Arrival {
                    time: t,
                    model: m,
                    class: classes[m],
                    deadline: deadlines[m].map(|d| t + d),
                });
            }
        }
    }
    all.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap());
    all
}

/// Split a merged arrival stream by a tenant→device placement: stream
/// `d` holds the arrivals of the tenants assigned to device `d`, with
/// each [`Arrival::model`] remapped to the tenant's rank among that
/// device's tenants in ascending global order — exactly the positional
/// index the per-device engine (DES station set or member server) sees,
/// and the member order [`crate::fleet::DevicePlan::tenants`] records.
/// Relative order (and therefore every per-device queueing decision) is
/// preserved.
pub fn split_by_placement(
    arrivals: &[Arrival],
    assignment: &[usize],
    devices: usize,
) -> Vec<Vec<Arrival>> {
    let mut local = vec![0usize; assignment.len()];
    let mut counts = vec![0usize; devices];
    for (i, &d) in assignment.iter().enumerate() {
        assert!(d < devices, "tenant {i} assigned to device {d} of {devices}");
        local[i] = counts[d];
        counts[d] += 1;
    }
    let mut out: Vec<Vec<Arrival>> = (0..devices).map(|_| Vec::new()).collect();
    for a in arrivals {
        let mut routed = *a;
        routed.model = local[a.model];
        out[assignment[a.model]].push(routed);
    }
    out
}

/// Solve for per-model rates that (a) hit a target TPU utilization ρ under
/// configuration `cfg` and (b) split the load by `shares` (Fig. 6c/7's
/// "each model's request rate is configured to generate an equal TPU load").
///
/// Shares are weights over models; returns `λ_i`.
pub fn rates_for_utilization(
    am: &AnalyticModel,
    tenants: &[Tenant],
    cfg: &Config,
    shares: &[f64],
    rho_target: f64,
) -> Vec<f64> {
    assert_eq!(tenants.len(), shares.len());
    assert!(rho_target > 0.0 && rho_target < 1.0);
    // Utilization is linear in a global rate scale factor until α flips
    // regimes; binary-search the scale (robust to the α discontinuity).
    let base: Vec<f64> = shares.to_vec();
    let util = |scale: f64| -> f64 {
        let scaled: Vec<Tenant> = tenants
            .iter()
            .zip(&base)
            .map(|(t, s)| Tenant {
                model: t.model.clone(),
                rate: s * scale,
            })
            .collect();
        am.tpu_utilization(&scaled, cfg)
    };
    let mut lo = 0.0f64;
    let mut hi = 1.0f64;
    while util(hi) < rho_target && hi < 1e9 {
        hi *= 2.0;
    }
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if util(mid) < rho_target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    base.iter().map(|s| s * hi).collect()
}

/// Like [`rates_for_utilization`], but accepting overload factors ρ ≥ 1
/// (which no stable queueing solution exists for): sub-critical targets
/// solve exactly; at or beyond saturation the rates solved at ρ = 0.7
/// are scaled linearly to the target. Uniform scaling keeps the mix
/// proportions — and therefore every α term — fixed, so TPU utilization
/// is exactly linear in the scale and the extrapolation is exact.
pub fn rates_for_load_factor(
    am: &AnalyticModel,
    tenants: &[Tenant],
    cfg: &Config,
    shares: &[f64],
    rho_target: f64,
) -> Vec<f64> {
    assert!(rho_target > 0.0);
    const BASE: f64 = 0.7;
    if rho_target < 1.0 {
        return rates_for_utilization(am, tenants, cfg, shares, rho_target);
    }
    let base = rates_for_utilization(am, tenants, cfg, shares, BASE);
    base.iter().map(|r| r * (rho_target / BASE)).collect()
}

/// Per-TPU-load-equalizing shares: each model contributes the same TPU busy
/// time, i.e. share_i ∝ 1 / s^TPU_i(P_i) (full-TPU service).
pub fn equal_tpu_load_shares(am: &AnalyticModel, tenants: &[Tenant]) -> Vec<f64> {
    tenants
        .iter()
        .map(|t| {
            let s = am
                .cost
                .tpu_service(&t.model, t.model.partition_points);
            if s > 0.0 {
                1.0 / s
            } else {
                1.0
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::tpu::CostModel;

    #[test]
    fn poisson_rate_is_respected() {
        let mut rng = Rng::new(42);
        let arr = generate_arrivals(&[RateSchedule::constant(5.0)], 2000.0, &mut rng);
        let rate = arr.len() as f64 / 2000.0;
        assert!((rate - 5.0).abs() < 0.2, "rate={rate}");
        // sorted
        for w in arr.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
    }

    #[test]
    fn rate_schedule_steps() {
        let s = RateSchedule::stepped(vec![(0.0, 1.0), (300.0, 3.0), (600.0, 5.0)]);
        assert_eq!(s.rate_at(0.0), 1.0);
        assert_eq!(s.rate_at(299.9), 1.0);
        assert_eq!(s.rate_at(300.0), 3.0);
        assert_eq!(s.rate_at(700.0), 5.0);
    }

    #[test]
    fn rate_schedule_sorts_unsorted_steps() {
        // rate_at's early-exit scan requires sorted steps; the
        // constructor must establish the order on any input.
        let unsorted = RateSchedule::stepped(vec![(600.0, 5.0), (0.0, 1.0), (300.0, 3.0)]);
        let sorted = RateSchedule::stepped(vec![(0.0, 1.0), (300.0, 3.0), (600.0, 5.0)]);
        assert_eq!(unsorted.steps(), sorted.steps());
        for t in [0.0, 299.9, 300.0, 599.9, 600.0, 1e4] {
            assert_eq!(unsorted.rate_at(t), sorted.rate_at(t), "t={t}");
        }
    }

    #[test]
    #[should_panic(expected = "bad rate step")]
    fn rate_schedule_rejects_negative_rate() {
        RateSchedule::stepped(vec![(0.0, -1.0)]);
    }

    #[test]
    fn classed_arrivals_carry_their_model_class() {
        let mut rng = Rng::new(11);
        let arr = generate_arrivals_classed(
            &[RateSchedule::constant(3.0), RateSchedule::constant(3.0)],
            &[SloClass::Interactive, SloClass::Batch],
            200.0,
            &mut rng,
        );
        assert!(!arr.is_empty());
        for a in &arr {
            let expect = if a.model == 0 {
                SloClass::Interactive
            } else {
                SloClass::Batch
            };
            assert_eq!(a.class, expect);
        }
        // The untagged generator defaults everything to Standard.
        let mut rng = Rng::new(11);
        let plain = generate_arrivals(&[RateSchedule::constant(3.0)], 50.0, &mut rng);
        assert!(plain.iter().all(|a| a.class == SloClass::Standard));
    }

    #[test]
    fn stepped_schedule_changes_density() {
        let mut rng = Rng::new(7);
        let s = RateSchedule::stepped(vec![(0.0, 1.0), (500.0, 8.0)]);
        let arr = generate_arrivals(&[s], 1000.0, &mut rng);
        let early = arr.iter().filter(|a| a.time < 500.0).count() as f64 / 500.0;
        let late = arr.iter().filter(|a| a.time >= 500.0).count() as f64 / 500.0;
        assert!((early - 1.0).abs() < 0.3, "early={early}");
        assert!((late - 8.0).abs() < 1.0, "late={late}");
    }

    #[test]
    fn diurnal_schedule_oscillates_around_base() {
        let s = RateSchedule::diurnal(10.0, 0.5, 100.0, 20, 200.0);
        // Peak near t = 25 (sin max), trough near t = 75 (sin min).
        assert!(s.rate_at(25.0) > 14.0, "peak={}", s.rate_at(25.0));
        assert!(s.rate_at(75.0) < 6.0, "trough={}", s.rate_at(75.0));
        // Never negative even with amplitude > 1.
        let deep = RateSchedule::diurnal(10.0, 1.5, 100.0, 20, 100.0);
        for k in 0..40 {
            assert!(deep.rate_at(k as f64 * 2.5) >= 0.0);
        }
    }

    #[test]
    fn flash_crowd_spikes_only_in_window() {
        let s = RateSchedule::flash_crowd(2.0, 12.0, 40.0, 60.0);
        assert_eq!(s.rate_at(0.0), 2.0);
        assert_eq!(s.rate_at(39.9), 2.0);
        assert_eq!(s.rate_at(40.0), 12.0);
        assert_eq!(s.rate_at(59.9), 12.0);
        assert_eq!(s.rate_at(60.0), 2.0);
    }

    #[test]
    fn drift_conserves_total_and_moves_mass() {
        let scheds = drift_schedules(10.0, &[3.0, 1.0], &[1.0, 3.0], 100.0, 8);
        assert_eq!(scheds.len(), 2);
        for t in [5.0, 30.0, 55.0, 90.0] {
            let sum = scheds[0].rate_at(t) + scheds[1].rate_at(t);
            assert!((sum - 10.0).abs() < 1e-9, "total at {t} = {sum}");
        }
        // Model 0 starts dominant and ends minor; model 1 the reverse.
        assert!(scheds[0].rate_at(1.0) > scheds[1].rate_at(1.0));
        assert!(scheds[0].rate_at(99.0) < scheds[1].rate_at(99.0));
    }

    #[test]
    fn split_by_placement_remaps_and_preserves_order() {
        let mut rng = Rng::new(21);
        let arr = generate_arrivals(
            &[
                RateSchedule::constant(2.0),
                RateSchedule::constant(3.0),
                RateSchedule::constant(1.0),
            ],
            300.0,
            &mut rng,
        );
        // Tenants 0 and 2 on device 1, tenant 1 alone on device 0.
        let streams = split_by_placement(&arr, &[1, 0, 1], 2);
        assert_eq!(streams.len(), 2);
        assert_eq!(streams[0].len() + streams[1].len(), arr.len());
        // Device 0 sees tenant 1 as its local model 0.
        assert!(streams[0].iter().all(|a| a.model == 0));
        assert_eq!(
            streams[0].len(),
            arr.iter().filter(|a| a.model == 1).count()
        );
        // Device 1 sees tenant 0 as local 0 and tenant 2 as local 1
        // (ascending global order), times preserved and sorted.
        assert_eq!(
            streams[1].iter().filter(|a| a.model == 0).count(),
            arr.iter().filter(|a| a.model == 0).count()
        );
        assert_eq!(
            streams[1].iter().filter(|a| a.model == 1).count(),
            arr.iter().filter(|a| a.model == 2).count()
        );
        for s in &streams {
            for w in s.windows(2) {
                assert!(w[0].time <= w[1].time);
            }
        }
    }

    #[test]
    #[should_panic(expected = "assigned to device")]
    fn split_by_placement_rejects_out_of_range_device() {
        split_by_placement(&[], &[2], 2);
    }

    #[test]
    fn two_streams_merge() {
        let mut rng = Rng::new(9);
        let arr = generate_arrivals(
            &[RateSchedule::constant(2.0), RateSchedule::constant(2.0)],
            1000.0,
            &mut rng,
        );
        let m0 = arr.iter().filter(|a| a.model == 0).count();
        let m1 = arr.iter().filter(|a| a.model == 1).count();
        assert!(m0 > 1500 && m1 > 1500);
    }

    #[test]
    fn annotated_arrivals_carry_absolute_deadlines() {
        let mut rng = Rng::new(13);
        let arr = generate_arrivals_annotated(
            &[RateSchedule::constant(3.0), RateSchedule::constant(3.0)],
            &[SloClass::Interactive, SloClass::Standard],
            &[Some(0.050), None],
            100.0,
            &mut rng,
        );
        assert!(!arr.is_empty());
        for a in &arr {
            match a.model {
                0 => {
                    let d = a.deadline.expect("model 0 annotated");
                    assert!((d - (a.time + 0.050)).abs() < 1e-12);
                }
                _ => assert_eq!(a.deadline, None),
            }
        }
        // Annotations do not perturb the stream: same seed, same times.
        let mut rng2 = Rng::new(13);
        let plain = generate_arrivals_classed(
            &[RateSchedule::constant(3.0), RateSchedule::constant(3.0)],
            &[SloClass::Interactive, SloClass::Standard],
            100.0,
            &mut rng2,
        );
        assert_eq!(plain.len(), arr.len());
        for (a, b) in arr.iter().zip(&plain) {
            assert_eq!(a.time, b.time);
            assert_eq!(a.model, b.model);
        }
    }

    #[test]
    fn load_factor_rates_extrapolate_linearly_past_saturation() {
        let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
        let tenants = vec![
            Tenant {
                model: synthetic_model("a", 5, 1_500_000, 400_000_000),
                rate: 0.0,
            },
            Tenant {
                model: synthetic_model("b", 5, 1_500_000, 300_000_000),
                rate: 0.0,
            },
        ];
        let cfg = Config::all_tpu(&tenants);
        let shares = [1.0, 1.0];
        // Sub-critical: defers to the exact solver.
        let sub = rates_for_load_factor(&am, &tenants, &cfg, &shares, 0.5);
        let exact = rates_for_utilization(&am, &tenants, &cfg, &shares, 0.5);
        assert_eq!(sub, exact);
        // Overload: 1.5 = (1.5/0.7) x the 0.7-solution, and the implied
        // utilization really is 1.5 (linear in the uniform scale).
        let over = rates_for_load_factor(&am, &tenants, &cfg, &shares, 1.5);
        let scaled: Vec<Tenant> = tenants
            .iter()
            .zip(&over)
            .map(|(t, r)| Tenant {
                model: t.model.clone(),
                rate: *r,
            })
            .collect();
        let rho = am.tpu_utilization(&scaled, &cfg);
        assert!((rho - 1.5).abs() < 0.03, "rho={rho}");
    }

    #[test]
    fn utilization_solver_hits_target() {
        let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
        let tenants = vec![
            Tenant {
                model: synthetic_model("a", 5, 1_500_000, 400_000_000),
                rate: 0.0,
            },
            Tenant {
                model: synthetic_model("b", 5, 1_500_000, 300_000_000),
                rate: 0.0,
            },
        ];
        let cfg = Config::all_tpu(&tenants);
        let rates = rates_for_utilization(&am, &tenants, &cfg, &[1.0, 1.0], 0.5);
        let scaled: Vec<Tenant> = tenants
            .iter()
            .zip(&rates)
            .map(|(t, r)| Tenant {
                model: t.model.clone(),
                rate: *r,
            })
            .collect();
        let rho = am.tpu_utilization(&scaled, &cfg);
        assert!((rho - 0.5).abs() < 0.01, "rho={rho}");
    }

    #[test]
    fn equal_load_shares_inverse_to_service() {
        let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
        let tenants = vec![
            Tenant {
                model: synthetic_model("slow", 5, 1_000_000, 2_000_000_000),
                rate: 0.0,
            },
            Tenant {
                model: synthetic_model("fast", 5, 1_000_000, 200_000_000),
                rate: 0.0,
            },
        ];
        let shares = equal_tpu_load_shares(&am, &tenants);
        assert!(shares[1] > shares[0]);
    }
}
