//! Algorithm 1 — Greedy Hill-Climbing Resource Allocation.
//!
//! Starts all-CPU, then repeatedly evaluates moving one or two layers of
//! each model from the CPU to the TPU (the 2-step lookahead lets the
//! search hop over transient latency spikes at intermediate partition
//! points), re-running `PropAlloc` for every candidate, and commits the
//! single best move. Terminates when no move improves the objective.
//!
//! Candidate evaluation runs on the incremental engine: per-tenant
//! [`PrefixTables`] make every cost query O(1) and the [`DeltaEvaluator`]
//! scores a move by updating only the moved tenant's contribution to the
//! cached aggregate sums, so one candidate costs O(1) + O(#core-changes)
//! instead of the naive O(n·L) re-evaluation (EXPERIMENTS.md §Perf). For
//! large tenant counts the candidate scan fans out over models with
//! `std::thread::scope`; the chunked reduction preserves the sequential
//! scan's first-best tie-breaking, so the parallel path is deterministic
//! and move-for-move identical. The pre-engine implementation is kept as
//! [`hill_climb_naive`] — the reference the property tests and the
//! before/after bench compare against.

use crate::analytic::{AnalyticModel, Config, DeltaEvaluator, Tenant};
use crate::tpu::PrefixTables;

use super::{prop_alloc, prop_alloc_tables_into, Allocation};

/// Below this tenant count the scan stays sequential: with O(1) delta
/// scoring a whole scan is ~2n·(PropAlloc + score) ≈ single-digit
/// microseconds per tenant, while `thread::scope` pays a fresh
/// spawn+join per scan (tens of microseconds) — fan-out only wins once
/// per-scan work clearly exceeds that. Embedded deployments never cross
/// this; large cloud-side mixes do. (A persistent worker pool would
/// lower the break-even; not worth it at the paper's scales.)
const PARALLEL_MIN_MODELS: usize = 32;

/// Lexicographic score: (remaining suffix length over core-starved models,
/// objective). When `K_max < n`, every all-CPU-ish configuration violates
/// constraint (8) and evaluates to an infinite objective — the starvation
/// measure decreases strictly as starved models migrate toward the TPU, so
/// the climb escapes the infinite plateau instead of terminating on it.
fn score(am: &AnalyticModel, tenants: &[Tenant], cfg: &Config) -> (usize, f64) {
    let starvation: usize = tenants
        .iter()
        .enumerate()
        .filter(|(i, t)| {
            cfg.partitions[*i] < t.model.partition_points && cfg.cores[*i] == 0
        })
        .map(|(i, t)| t.model.partition_points - cfg.partitions[i])
        .sum();
    (starvation, am.objective(tenants, cfg))
}

fn lex_less(a: (usize, f64), b: (usize, f64)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
}

/// One winning candidate: (model, step, score, PropAlloc core vector).
type BestMove = (usize, usize, (usize, f64), Vec<usize>);

/// Scan `models` (a contiguous index range) for the best 1/2-step move
/// from `partitions`, scoring each candidate incrementally. Returns the
/// chunk's winner and the number of candidates scored.
fn scan_range(
    ev: &DeltaEvaluator,
    tenants: &[Tenant],
    tables: &[PrefixTables],
    partitions: &[usize],
    k_max: usize,
    models: std::ops::Range<usize>,
) -> (Option<BestMove>, usize) {
    let mut cand = partitions.to_vec();
    let mut cand_cores = vec![0usize; tenants.len()];
    let mut best: Option<BestMove> = None;
    let mut evaluations = 0usize;
    for m in models {
        for h in 1..=2usize {
            if partitions[m] + h > tenants[m].model.partition_points {
                continue;
            }
            // Mutate-and-revert: no per-candidate partition clone.
            cand[m] = partitions[m] + h;
            prop_alloc_tables_into(tables, tenants, &cand, k_max, &mut cand_cores);
            let sc = ev.score_move(m, cand[m], &cand_cores);
            cand[m] = partitions[m];
            evaluations += 1;
            let better = match &best {
                None => true,
                Some((_, _, l, _)) => lex_less(sc, *l),
            };
            if better {
                if let Some((bm, bh, bl, bc)) = &mut best {
                    // Reuse the winner's buffer instead of reallocating.
                    *bm = m;
                    *bh = h;
                    *bl = sc;
                    std::mem::swap(bc, &mut cand_cores);
                } else {
                    best = Some((m, h, sc, cand_cores.clone()));
                }
            }
        }
    }
    (best, evaluations)
}

/// Reduce per-chunk winners in model order, replicating the sequential
/// scan's strict-improvement (first-best-wins) tie-breaking.
fn reduce_best(chunks: Vec<(Option<BestMove>, usize)>) -> (Option<BestMove>, usize) {
    let mut best: Option<BestMove> = None;
    let mut evaluations = 0usize;
    for (cand, ev) in chunks {
        evaluations += ev;
        if let Some(c) = cand {
            let better = match &best {
                None => true,
                Some((_, _, l, _)) => lex_less(c.2, *l),
            };
            if better {
                best = Some(c);
            }
        }
    }
    (best, evaluations)
}

/// Hill climb over a prebuilt table set. Callers that re-plan repeatedly
/// for a fixed tenant mix (the coordinator's re-allocator thread, the
/// simulator's reconfiguration policy) build the tables once and amortize
/// them across every decision.
pub fn hill_climb_with_tables(
    am: &AnalyticModel,
    tenants: &[Tenant],
    tables: &[PrefixTables],
    k_max: usize,
) -> Allocation {
    let n = tenants.len();
    let mut partitions = vec![0usize; n];
    let mut cores = vec![0usize; n];
    prop_alloc_tables_into(tables, tenants, &partitions, k_max, &mut cores);
    let mut ev = DeltaEvaluator::new(
        am,
        tenants,
        tables,
        &Config {
            partitions: partitions.clone(),
            cores: cores.clone(),
        },
    );
    let mut current = ev.score();
    let mut evaluations = 1usize;

    loop {
        let (best, scanned) = if n >= PARALLEL_MIN_MODELS {
            let workers = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
                .min(n);
            let chunk = n.div_ceil(workers);
            let ev_ref = &ev;
            let parts_ref = &partitions;
            let results: Vec<(Option<BestMove>, usize)> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..workers)
                    .map(|w| {
                        let lo = w * chunk;
                        let hi = ((w + 1) * chunk).min(n);
                        s.spawn(move || {
                            scan_range(ev_ref, tenants, tables, parts_ref, k_max, lo..hi)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            reduce_best(results)
        } else {
            scan_range(&ev, tenants, tables, &partitions, k_max, 0..n)
        };
        evaluations += scanned;
        match best {
            Some((m, h, sc, k_new)) if lex_less(sc, current) => {
                partitions[m] += h;
                cores = k_new;
                ev.commit(m, partitions[m], &cores);
                current = sc;
            }
            _ => break,
        }
    }

    // `ev` was rebuilt from scratch on the last commit, so its objective
    // is bit-identical to a fresh table-backed evaluation of the final
    // configuration (and ≤1e-9 rel from the naive `objective()` — the
    // property tests pin both).
    Allocation {
        predicted_objective: ev.objective(),
        config: Config { partitions, cores },
        evaluations,
    }
}

/// Algorithm 1 with a fresh table build (one-shot planning call sites).
pub fn hill_climb(am: &AnalyticModel, tenants: &[Tenant], k_max: usize) -> Allocation {
    let tables = PrefixTables::for_tenants(&am.cost, tenants);
    hill_climb_with_tables(am, tenants, &tables, k_max)
}

/// The pre-engine implementation: every candidate re-runs the naive
/// O(n·L) `objective()`. Kept as the reference for the incremental-vs-
/// naive property tests and the EXPERIMENTS.md §Perf before/after bench.
pub fn hill_climb_naive(am: &AnalyticModel, tenants: &[Tenant], k_max: usize) -> Allocation {
    let n = tenants.len();
    let mut partitions = vec![0usize; n];
    let mut cores = prop_alloc(&am.cost, tenants, &partitions, k_max);
    let mut current = score(
        am,
        tenants,
        &Config {
            partitions: partitions.clone(),
            cores: cores.clone(),
        },
    );
    let mut evaluations = 1usize;

    loop {
        let mut best: Option<BestMove> = None;
        for m in 0..n {
            for h in 1..=2usize {
                if partitions[m] + h > tenants[m].model.partition_points {
                    continue;
                }
                let mut cand = partitions.clone();
                cand[m] += h;
                let cand_cores = prop_alloc(&am.cost, tenants, &cand, k_max);
                let sc = score(
                    am,
                    tenants,
                    &Config {
                        partitions: cand,
                        cores: cand_cores.clone(),
                    },
                );
                evaluations += 1;
                let better = match &best {
                    None => true,
                    Some((_, _, l, _)) => lex_less(sc, *l),
                };
                if better {
                    best = Some((m, h, sc, cand_cores));
                }
            }
        }
        match best {
            Some((m, h, sc, k_new)) if lex_less(sc, current) => {
                partitions[m] += h;
                cores = k_new;
                current = sc;
            }
            _ => break,
        }
    }

    Allocation {
        config: Config { partitions, cores },
        predicted_objective: current.1,
        evaluations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::{check_constraints, AnalyticModel, Tenant};
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::tpu::CostModel;

    fn am() -> AnalyticModel {
        AnalyticModel::new(CostModel::new(HardwareSpec::default()))
    }

    fn tenant(name: &str, segs: usize, mb_total: f64, gflops: f64, rate: f64) -> Tenant {
        Tenant {
            model: synthetic_model(
                name,
                segs,
                (mb_total * 1e6 / segs as f64) as u64,
                (gflops * 1e9 / segs as f64) as u64,
            ),
            rate,
        }
    }

    #[test]
    fn single_small_model_prefers_full_tpu() {
        // Fits in SRAM, TPU much faster: the climb should reach p = P.
        let am = am();
        let tenants = vec![tenant("small", 5, 4.0, 1.0, 2.0)];
        let a = hill_climb(&am, &tenants, 4);
        assert_eq!(a.config.partitions[0], 5);
        assert_eq!(a.config.cores[0], 0);
        check_constraints(&tenants, &a.config, 4).unwrap();
    }

    #[test]
    fn oversized_model_prefers_partial_offload() {
        // 40 MB model: full-TPU pays heavy intra-swap; the climb should
        // stop at a prefix that balances swap vs CPU time.
        let am = am();
        let tenants = vec![tenant("big", 10, 40.0, 12.0, 2.0)];
        let a = hill_climb(&am, &tenants, 4);
        let p = a.config.partitions[0];
        assert!(p > 0, "should use the TPU at all");
        assert!(p < 10, "should not pay full intra-model swapping");
        assert!(a.config.cores[0] >= 1);
        check_constraints(&tenants, &a.config, 4).unwrap();
    }

    #[test]
    fn beats_all_cpu_and_all_tpu() {
        let am = am();
        let tenants = vec![tenant("big", 10, 40.0, 12.0, 2.0), tenant("small", 5, 4.0, 0.5, 2.0)];
        let a = hill_climb(&am, &tenants, 4);
        let all_cpu = Config {
            partitions: vec![0, 0],
            cores: prop_alloc(&am.cost, &tenants, &[0, 0], 4),
        };
        let all_tpu = Config {
            partitions: vec![10, 5],
            cores: vec![0, 0],
        };
        let best = am.objective(&tenants, &a.config);
        assert!(best <= am.objective(&tenants, &all_cpu) + 1e-12);
        assert!(best <= am.objective(&tenants, &all_tpu) + 1e-12);
    }

    #[test]
    fn result_is_local_optimum_for_single_steps() {
        let am = am();
        let tenants = vec![tenant("a", 8, 20.0, 4.0, 3.0), tenant("b", 6, 12.0, 2.0, 1.0)];
        let a = hill_climb(&am, &tenants, 4);
        let base = am.objective(&tenants, &a.config);
        // No single +1/+2 move may improve further (that's the loop exit).
        for m in 0..2 {
            for h in 1..=2 {
                let mut p = a.config.partitions.clone();
                if p[m] + h > tenants[m].model.partition_points {
                    continue;
                }
                p[m] += h;
                let k = prop_alloc(&am.cost, &tenants, &p, 4);
                let obj = am.objective(&tenants, &Config { partitions: p, cores: k });
                assert!(obj >= base - 1e-12);
            }
        }
    }

    #[test]
    fn decision_overhead_is_bounded() {
        // Paper: < 2 ms per invocation. Structurally: O(n · P · moves).
        let am = am();
        let tenants: Vec<Tenant> = (0..4)
            .map(|i| tenant(&format!("m{i}"), 11, 20.0, 6.0, 1.0 + i as f64))
            .collect();
        let a = hill_climb(&am, &tenants, 4);
        // Worst case: each of Σ P_i = 44 commits scans 4 models × 2 steps.
        assert!(a.evaluations <= 1 + 44 * 8 + 8);
    }

    #[test]
    fn zero_rate_models_dont_block() {
        let am = am();
        let tenants = vec![tenant("idle", 5, 4.0, 1.0, 0.0), tenant("busy", 5, 4.0, 1.0, 3.0)];
        let a = hill_climb(&am, &tenants, 4);
        check_constraints(&tenants, &a.config, 4).unwrap();
        assert!(am.objective(&tenants, &a.config).is_finite());
    }

    #[test]
    fn engine_matches_naive_reference() {
        // The incremental climb must take the exact same moves as the
        // naive one on representative mixes.
        let am = am();
        for tenants in [
            vec![tenant("big", 10, 40.0, 12.0, 2.0)],
            vec![tenant("big", 10, 40.0, 12.0, 2.0), tenant("small", 5, 4.0, 0.5, 2.0)],
            vec![
                tenant("a", 8, 20.0, 4.0, 3.0),
                tenant("b", 6, 12.0, 2.0, 1.0),
                tenant("c", 9, 30.0, 6.0, 0.5),
            ],
        ] {
            let fast = hill_climb(&am, &tenants, 4);
            let slow = hill_climb_naive(&am, &tenants, 4);
            assert_eq!(fast.config, slow.config);
            assert_eq!(fast.evaluations, slow.evaluations);
        }
    }

    #[test]
    fn parallel_scan_is_deterministic_and_feasible() {
        // n ≥ PARALLEL_MIN_MODELS exercises the thread::scope fan-out;
        // two runs must agree exactly, and the result must be feasible.
        let am = am();
        let tenants: Vec<Tenant> = (0..PARALLEL_MIN_MODELS + 2)
            .map(|i| {
                tenant(
                    &format!("m{i}"),
                    4 + (i % 5),
                    6.0 + i as f64,
                    1.0 + (i % 3) as f64,
                    0.2 + 0.1 * i as f64,
                )
            })
            .collect();
        let k_max = tenants.len(); // every suffix can hold a core
        let a = hill_climb(&am, &tenants, k_max);
        let b = hill_climb(&am, &tenants, k_max);
        assert_eq!(a.config, b.config);
        assert_eq!(a.evaluations, b.evaluations);
        check_constraints(&tenants, &a.config, k_max).unwrap();
    }
}
