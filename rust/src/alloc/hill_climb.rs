//! Algorithm 1 — Greedy Hill-Climbing Resource Allocation.
//!
//! Starts all-CPU, then repeatedly evaluates moving one or two layers of
//! each model from the CPU to the TPU (the 2-step lookahead lets the
//! search hop over transient latency spikes at intermediate partition
//! points), re-running `PropAlloc` for every candidate, and commits the
//! single best move. Terminates when no move improves the objective.

use crate::analytic::{AnalyticModel, Config, Tenant};

use super::{prop_alloc, Allocation};

/// Lexicographic score: (remaining suffix length over core-starved models,
/// objective). When `K_max < n`, every all-CPU-ish configuration violates
/// constraint (8) and evaluates to an infinite objective — the starvation
/// measure decreases strictly as starved models migrate toward the TPU, so
/// the climb escapes the infinite plateau instead of terminating on it.
fn score(am: &AnalyticModel, tenants: &[Tenant], cfg: &Config) -> (usize, f64) {
    let starvation: usize = tenants
        .iter()
        .enumerate()
        .filter(|(i, t)| {
            cfg.partitions[*i] < t.model.partition_points && cfg.cores[*i] == 0
        })
        .map(|(i, t)| t.model.partition_points - cfg.partitions[i])
        .sum();
    (starvation, am.objective(tenants, cfg))
}

fn lex_less(a: (usize, f64), b: (usize, f64)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
}

pub fn hill_climb(am: &AnalyticModel, tenants: &[Tenant], k_max: usize) -> Allocation {
    let n = tenants.len();
    let mut partitions = vec![0usize; n];
    let mut cores = prop_alloc(&am.cost, tenants, &partitions, k_max);
    let mut current = score(
        am,
        tenants,
        &Config {
            partitions: partitions.clone(),
            cores: cores.clone(),
        },
    );
    let mut evaluations = 1usize;

    loop {
        let mut best: Option<(usize, usize, (usize, f64), Vec<usize>)> = None;
        for m in 0..n {
            for h in 1..=2usize {
                if partitions[m] + h > tenants[m].model.partition_points {
                    continue;
                }
                let mut cand = partitions.clone();
                cand[m] += h;
                let cand_cores = prop_alloc(&am.cost, tenants, &cand, k_max);
                let sc = score(
                    am,
                    tenants,
                    &Config {
                        partitions: cand.clone(),
                        cores: cand_cores.clone(),
                    },
                );
                evaluations += 1;
                let better = match &best {
                    None => true,
                    Some((_, _, l, _)) => lex_less(sc, *l),
                };
                if better {
                    best = Some((m, h, sc, cand_cores));
                }
            }
        }
        match best {
            Some((m, h, sc, k_new)) if lex_less(sc, current) => {
                partitions[m] += h;
                cores = k_new;
                current = sc;
            }
            _ => break,
        }
    }

    Allocation {
        config: Config { partitions, cores },
        predicted_objective: current.1,
        evaluations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::{check_constraints, AnalyticModel, Tenant};
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::tpu::CostModel;

    fn am() -> AnalyticModel {
        AnalyticModel::new(CostModel::new(HardwareSpec::default()))
    }

    fn tenant(name: &str, segs: usize, mb_total: f64, gflops: f64, rate: f64) -> Tenant {
        Tenant {
            model: synthetic_model(
                name,
                segs,
                (mb_total * 1e6 / segs as f64) as u64,
                (gflops * 1e9 / segs as f64) as u64,
            ),
            rate,
        }
    }

    #[test]
    fn single_small_model_prefers_full_tpu() {
        // Fits in SRAM, TPU much faster: the climb should reach p = P.
        let am = am();
        let tenants = vec![tenant("small", 5, 4.0, 1.0, 2.0)];
        let a = hill_climb(&am, &tenants, 4);
        assert_eq!(a.config.partitions[0], 5);
        assert_eq!(a.config.cores[0], 0);
        check_constraints(&tenants, &a.config, 4).unwrap();
    }

    #[test]
    fn oversized_model_prefers_partial_offload() {
        // 40 MB model: full-TPU pays heavy intra-swap; the climb should
        // stop at a prefix that balances swap vs CPU time.
        let am = am();
        let tenants = vec![tenant("big", 10, 40.0, 12.0, 2.0)];
        let a = hill_climb(&am, &tenants, 4);
        let p = a.config.partitions[0];
        assert!(p > 0, "should use the TPU at all");
        assert!(p < 10, "should not pay full intra-model swapping");
        assert!(a.config.cores[0] >= 1);
        check_constraints(&tenants, &a.config, 4).unwrap();
    }

    #[test]
    fn beats_all_cpu_and_all_tpu() {
        let am = am();
        let tenants = vec![tenant("big", 10, 40.0, 12.0, 2.0), tenant("small", 5, 4.0, 0.5, 2.0)];
        let a = hill_climb(&am, &tenants, 4);
        let all_cpu = Config {
            partitions: vec![0, 0],
            cores: prop_alloc(&am.cost, &tenants, &[0, 0], 4),
        };
        let all_tpu = Config {
            partitions: vec![10, 5],
            cores: vec![0, 0],
        };
        let best = am.objective(&tenants, &a.config);
        assert!(best <= am.objective(&tenants, &all_cpu) + 1e-12);
        assert!(best <= am.objective(&tenants, &all_tpu) + 1e-12);
    }

    #[test]
    fn result_is_local_optimum_for_single_steps() {
        let am = am();
        let tenants = vec![tenant("a", 8, 20.0, 4.0, 3.0), tenant("b", 6, 12.0, 2.0, 1.0)];
        let a = hill_climb(&am, &tenants, 4);
        let base = am.objective(&tenants, &a.config);
        // No single +1/+2 move may improve further (that's the loop exit).
        for m in 0..2 {
            for h in 1..=2 {
                let mut p = a.config.partitions.clone();
                if p[m] + h > tenants[m].model.partition_points {
                    continue;
                }
                p[m] += h;
                let k = prop_alloc(&am.cost, &tenants, &p, 4);
                let obj = am.objective(&tenants, &Config { partitions: p, cores: k });
                assert!(obj >= base - 1e-12);
            }
        }
    }

    #[test]
    fn decision_overhead_is_bounded() {
        // Paper: < 2 ms per invocation. Structurally: O(n · P · moves).
        let am = am();
        let tenants: Vec<Tenant> = (0..4)
            .map(|i| tenant(&format!("m{i}"), 11, 20.0, 6.0, 1.0 + i as f64))
            .collect();
        let a = hill_climb(&am, &tenants, 4);
        // Worst case: each of Σ P_i = 44 commits scans 4 models × 2 steps.
        assert!(a.evaluations <= 1 + 44 * 8 + 8);
    }

    #[test]
    fn zero_rate_models_dont_block() {
        let am = am();
        let tenants = vec![tenant("idle", 5, 4.0, 1.0, 0.0), tenant("busy", 5, 4.0, 1.0, 3.0)];
        let a = hill_climb(&am, &tenants, 4);
        check_constraints(&tenants, &a.config, 4).unwrap();
        assert!(am.objective(&tenants, &a.config).is_finite());
    }
}
