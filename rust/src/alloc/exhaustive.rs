//! Exhaustive NLIP reference solver (Eq. 5–9) for small instances.
//!
//! Enumerates every feasible `(P, K)` pair; used by tests to measure the
//! hill-climb's optimality gap and by the ablation bench. Complexity is
//! Π (P_i + 1) × compositions(K_max), so keep it to ≤ 4 models (the
//! `n <= 4` assert below is the hard line). Leaf configurations are
//! scored through [`objective_with_tables`], so each evaluation is O(n)
//! instead of O(n·L).
//!
//! Returns `None` when no enumerated configuration satisfies constraints
//! (6)–(9) — callers decide whether that is a hard error.

use crate::analytic::{objective_with_tables, AnalyticModel, Config, Tenant};
use crate::tpu::PrefixTables;

use super::Allocation;

/// Exhaustive search with a fresh table build. `None` iff no feasible
/// configuration exists.
pub fn exhaustive_best(
    am: &AnalyticModel,
    tenants: &[Tenant],
    k_max: usize,
) -> Option<Allocation> {
    let tables = PrefixTables::for_tenants(&am.cost, tenants);
    exhaustive_best_with_tables(am, tenants, &tables, k_max)
}

/// Exhaustive search over prebuilt tables.
pub fn exhaustive_best_with_tables(
    am: &AnalyticModel,
    tenants: &[Tenant],
    tables: &[PrefixTables],
    k_max: usize,
) -> Option<Allocation> {
    let n = tenants.len();
    assert!(n <= 4, "exhaustive solver is for small instances");
    let mut best: Option<(f64, Config)> = None;
    let mut evaluations = 0usize;

    let mut partitions = vec![0usize; n];
    enumerate_partitions(
        am,
        tenants,
        tables,
        k_max,
        0,
        &mut partitions,
        &mut best,
        &mut evaluations,
    );

    best.map(|(obj, config)| Allocation {
        config,
        predicted_objective: obj,
        evaluations,
    })
}

#[allow(clippy::too_many_arguments)]
fn enumerate_partitions(
    am: &AnalyticModel,
    tenants: &[Tenant],
    tables: &[PrefixTables],
    k_max: usize,
    i: usize,
    partitions: &mut Vec<usize>,
    best: &mut Option<(f64, Config)>,
    evaluations: &mut usize,
) {
    let n = tenants.len();
    if i == n {
        let mut cores = vec![0usize; n];
        enumerate_cores(
            am,
            tenants,
            tables,
            k_max,
            0,
            k_max,
            partitions,
            &mut cores,
            best,
            evaluations,
        );
        return;
    }
    for p in 0..=tenants[i].model.partition_points {
        partitions[i] = p;
        enumerate_partitions(am, tenants, tables, k_max, i + 1, partitions, best, evaluations);
    }
}

#[allow(clippy::too_many_arguments)]
fn enumerate_cores(
    am: &AnalyticModel,
    tenants: &[Tenant],
    tables: &[PrefixTables],
    k_max: usize,
    i: usize,
    remaining: usize,
    partitions: &[usize],
    cores: &mut Vec<usize>,
    best: &mut Option<(f64, Config)>,
    evaluations: &mut usize,
) {
    let n = tenants.len();
    if i == n {
        let cfg = Config {
            partitions: partitions.to_vec(),
            cores: cores.clone(),
        };
        if crate::analytic::check_constraints(tenants, &cfg, k_max).is_err() {
            return;
        }
        let obj = objective_with_tables(am, tenants, tables, &cfg);
        *evaluations += 1;
        if best.as_ref().map(|(b, _)| obj < *b).unwrap_or(true) {
            *best = Some((obj, cfg));
        }
        return;
    }
    if partitions[i] == tenants[i].model.partition_points {
        cores[i] = 0;
        enumerate_cores(
            am,
            tenants,
            tables,
            k_max,
            i + 1,
            remaining,
            partitions,
            cores,
            best,
            evaluations,
        );
    } else {
        for k in 1..=remaining {
            cores[i] = k;
            enumerate_cores(
                am,
                tenants,
                tables,
                k_max,
                i + 1,
                remaining - k,
                partitions,
                cores,
                best,
                evaluations,
            );
        }
        cores[i] = 0; // reset for caller
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::hill_climb;
    use crate::analytic::AnalyticModel;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::tpu::CostModel;

    fn tenant(name: &str, segs: usize, mb: f64, gflops: f64, rate: f64) -> Tenant {
        Tenant {
            model: synthetic_model(
                name,
                segs,
                (mb * 1e6 / segs as f64) as u64,
                (gflops * 1e9 / segs as f64) as u64,
            ),
            rate,
        }
    }

    #[test]
    fn finds_global_optimum_single_model() {
        let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
        let tenants = vec![tenant("big", 8, 30.0, 8.0, 2.0)];
        let ex = exhaustive_best(&am, &tenants, 4).expect("feasible");
        // brute-force sanity: every configuration is ≥ the reported best
        for p in 0..=8usize {
            for k in 0..=4usize {
                let feasible = if p == 8 { k == 0 } else { k >= 1 };
                if !feasible {
                    continue;
                }
                let cfg = Config {
                    partitions: vec![p],
                    cores: vec![k],
                };
                assert!(am.objective(&tenants, &cfg) >= ex.predicted_objective - 1e-12);
            }
        }
    }

    #[test]
    fn hill_climb_matches_exhaustive_on_easy_instances() {
        let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
        for (mb, gf, rate) in [(4.0, 1.0, 2.0), (30.0, 8.0, 2.0), (16.0, 4.0, 5.0)] {
            let tenants = vec![tenant("m", 8, mb, gf, rate)];
            let ex = exhaustive_best(&am, &tenants, 4).expect("feasible");
            let hc = hill_climb(&am, &tenants, 4);
            // Alg. 1 is a heuristic; on single-model instances it should be
            // within a small factor of optimal (typically exact).
            assert!(
                hc.predicted_objective <= ex.predicted_objective * 1.25 + 1e-9,
                "hc={} ex={} (mb={mb})",
                hc.predicted_objective,
                ex.predicted_objective
            );
        }
    }

    #[test]
    fn two_model_optimality_gap_small() {
        let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
        let tenants = vec![tenant("a", 6, 20.0, 5.0, 2.0), tenant("b", 5, 7.0, 0.4, 2.0)];
        let ex = exhaustive_best(&am, &tenants, 4).expect("feasible");
        let hc = hill_climb(&am, &tenants, 4);
        assert!(hc.predicted_objective <= ex.predicted_objective * 1.3 + 1e-9);
        assert!(ex.evaluations > hc.evaluations, "exhaustive must search more");
    }

    #[test]
    fn no_tenants_yields_trivial_allocation_not_panic() {
        // Degenerate input: the empty mix has exactly one (empty, feasible)
        // configuration; the old `.expect` path made any infeasibility a
        // panic — the Option API lets callers handle it.
        let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
        let out = exhaustive_best(&am, &[], 4);
        let a = out.expect("empty mix is trivially feasible");
        assert!(a.config.partitions.is_empty());
        assert_eq!(a.predicted_objective, 0.0);
    }

    #[test]
    fn zero_cores_forces_full_tpu_optimum() {
        // With K_max = 0 every CPU-suffix config violates constraint (8);
        // the solver must still return the all-TPU configuration instead
        // of panicking.
        let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
        let tenants = vec![tenant("m", 4, 6.0, 1.0, 1.0)];
        let a = exhaustive_best(&am, &tenants, 0).expect("all-TPU is feasible");
        assert_eq!(a.config.partitions, vec![4]);
        assert_eq!(a.config.cores, vec![0]);
    }
}
