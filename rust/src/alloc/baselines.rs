//! The paper's comparison baselines (Section V-A3).
//!
//! * **Edge TPU Compiler** — the industry default: every model compiled
//!   fully onto the TPU (p = P, no cores), co-located models share SRAM
//!   and pay inter-model swapping.
//! * **Threshold-based Partitioning** — per-model heuristic: walk layers
//!   from the last one and offload to the CPU while the layer's CPU time
//!   is within 10% of its TPU time; ignores queuing and multi-tenancy.

use crate::analytic::{objective_with_tables, Config, Tenant};
use crate::tpu::{CostModel, PrefixTables};

use super::{prop_alloc_tables, Allocation};
use crate::analytic::AnalyticModel;

/// Baseline 1: default Edge TPU compiler co-compilation (fresh tables).
pub fn edge_tpu_compiler(am: &AnalyticModel, tenants: &[Tenant]) -> Allocation {
    let tables = PrefixTables::for_tenants(&am.cost, tenants);
    edge_tpu_compiler_with_tables(am, tenants, &tables)
}

/// Baseline 1 over prebuilt tables — experiment sweeps that score many
/// policies on one mix amortize the build across all of them.
pub fn edge_tpu_compiler_with_tables(
    am: &AnalyticModel,
    tenants: &[Tenant],
    tables: &[PrefixTables],
) -> Allocation {
    let config = Config::all_tpu(tenants);
    Allocation {
        predicted_objective: objective_with_tables(am, tenants, tables, &config),
        config,
        evaluations: 1,
    }
}

/// Baseline 2: threshold-based partitioning (10% rule), cores via
/// PropAlloc (fresh tables).
pub fn threshold_partitioning(
    am: &AnalyticModel,
    tenants: &[Tenant],
    k_max: usize,
    threshold: f64,
) -> Allocation {
    let tables = PrefixTables::for_tenants(&am.cost, tenants);
    threshold_partitioning_with_tables(am, tenants, &tables, k_max, threshold)
}

/// Baseline 2 over prebuilt tables. The per-layer CPU-vs-TPU walk is
/// inherently per-segment; scoring and core allocation are table-backed.
pub fn threshold_partitioning_with_tables(
    am: &AnalyticModel,
    tenants: &[Tenant],
    tables: &[PrefixTables],
    k_max: usize,
    threshold: f64,
) -> Allocation {
    let cost: &CostModel = &am.cost;
    let mut partitions = Vec::with_capacity(tenants.len());
    for t in tenants {
        let pp = t.model.partition_points;
        let mut p = pp;
        // Walk backwards from the last segment; offload while CPU ≈ TPU.
        while p > 0 {
            let seg = &t.model.segments[p - 1];
            let cpu = cost.cpu_segment_time(seg);
            let tpu = cost.tpu_segment_time(&t.model, seg);
            if cpu <= (1.0 + threshold) * tpu {
                p -= 1;
            } else {
                break;
            }
        }
        partitions.push(p);
    }
    let cores = prop_alloc_tables(tables, tenants, &partitions, k_max);
    let config = Config { partitions, cores };
    Allocation {
        predicted_objective: objective_with_tables(am, tenants, tables, &config),
        config,
        evaluations: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::AnalyticModel;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::tpu::CostModel;

    fn am() -> AnalyticModel {
        AnalyticModel::new(CostModel::new(HardwareSpec::default()))
    }

    fn tenants() -> Vec<Tenant> {
        vec![
            Tenant {
                model: synthetic_model("big", 10, 4_000_000, 1_200_000_000),
                rate: 2.0,
            },
            Tenant {
                model: synthetic_model("small", 5, 800_000, 100_000_000),
                rate: 2.0,
            },
        ]
    }

    #[test]
    fn compiler_baseline_is_all_tpu() {
        let am = am();
        let t = tenants();
        let a = edge_tpu_compiler(&am, &t);
        assert_eq!(a.config.partitions, vec![10, 5]);
        assert_eq!(a.config.cores, vec![0, 0]);
    }

    #[test]
    fn threshold_offloads_trailing_layers() {
        let am = am();
        let t = tenants();
        let a = threshold_partitioning(&am, &t, 4, 0.10);
        // The synthetic util profile decays to ~parity at the tail, so at
        // least the last segment must offload, but not the whole model.
        assert!(a.config.partitions[0] < 10);
        assert!(a.config.partitions[0] > 0);
        // Offloaded models have cores; check constraint 8 holds.
        crate::analytic::check_constraints(&t, &a.config, 4).unwrap();
    }

    #[test]
    fn threshold_ignores_rates() {
        // Same models, wildly different rates -> identical partitions
        // (that's the baseline's blind spot the paper calls out).
        let am = am();
        let mut t = tenants();
        let a1 = threshold_partitioning(&am, &t, 4, 0.10);
        t[0].rate = 100.0;
        let a2 = threshold_partitioning(&am, &t, 4, 0.10);
        assert_eq!(a1.config.partitions, a2.config.partitions);
    }

    #[test]
    fn swapless_never_worse_than_baselines() {
        let am = am();
        let t = tenants();
        let hc = crate::alloc::hill_climb(&am, &t, 4);
        let co = edge_tpu_compiler(&am, &t);
        let th = threshold_partitioning(&am, &t, 4, 0.10);
        assert!(hc.predicted_objective <= co.predicted_objective + 1e-12);
        assert!(hc.predicted_objective <= th.predicted_objective + 1e-12);
    }
}
