//! Resource allocation: the paper's greedy hill-climbing search (Alg. 1),
//! the proportional fair-share core allocator, an exhaustive NLIP reference
//! solver for small instances, and the two baselines from Section V-A3.

pub mod baselines;
pub mod exhaustive;
pub mod hill_climb;

pub use baselines::{
    edge_tpu_compiler, edge_tpu_compiler_with_tables, threshold_partitioning,
    threshold_partitioning_with_tables,
};
pub use exhaustive::{exhaustive_best, exhaustive_best_with_tables};
pub use hill_climb::{hill_climb, hill_climb_naive, hill_climb_with_tables};

use crate::analytic::{Config, Tenant};
use crate::tpu::{CostModel, PrefixTables};

/// `PropAlloc` (Alg. 1, lines 2 & 10): distribute the `K_max` physical
/// cores across models with CPU suffixes, proportionally to each model's
/// CPU workload `λ_i · s^CPU_1core(suffix_i)`, with the constraint-(8)
/// floor of one core per suffix-bearing model. Largest-remainder rounding
/// keeps shares integral and the total ≤ `K_max`.
///
/// If more models need a core than cores exist, the lowest-workload models
/// are left with zero cores — the resulting configuration evaluates to an
/// infinite latency and the hill-climb moves those models off the CPU.
pub fn prop_alloc(
    cost: &CostModel,
    tenants: &[Tenant],
    partitions: &[usize],
    k_max: usize,
) -> Vec<usize> {
    let mut cores = vec![0usize; tenants.len()];
    prop_alloc_impl(
        |i| cost.cpu_service(&tenants[i].model, partitions[i]),
        tenants,
        partitions,
        k_max,
        &mut cores,
    );
    cores
}

/// `PropAlloc` over prebuilt [`PrefixTables`]: the per-model CPU suffix
/// time is an O(1) lookup instead of an O(L) segment sum. Same algorithm
/// on bit-identical inputs, so the output matches [`prop_alloc`] exactly.
pub fn prop_alloc_tables(
    tables: &[PrefixTables],
    tenants: &[Tenant],
    partitions: &[usize],
    k_max: usize,
) -> Vec<usize> {
    let mut cores = vec![0usize; tenants.len()];
    prop_alloc_tables_into(tables, tenants, partitions, k_max, &mut cores);
    cores
}

/// Allocation-light variant for the hill climb's candidate scan: writes
/// the core vector into a caller-owned buffer (resized + zeroed here).
pub fn prop_alloc_tables_into(
    tables: &[PrefixTables],
    tenants: &[Tenant],
    partitions: &[usize],
    k_max: usize,
    cores: &mut Vec<usize>,
) {
    assert_eq!(tables.len(), tenants.len());
    prop_alloc_impl(
        |i| tables[i].cpu_service(partitions[i]),
        tenants,
        partitions,
        k_max,
        cores,
    );
}

/// The shared PropAlloc algorithm; `cpu_service` abstracts the cost
/// backend (naive segment sums vs prefix tables).
fn prop_alloc_impl<F: Fn(usize) -> f64>(
    cpu_service: F,
    tenants: &[Tenant],
    partitions: &[usize],
    k_max: usize,
    cores: &mut Vec<usize>,
) {
    let n = tenants.len();
    assert_eq!(partitions.len(), n);
    cores.clear();
    cores.resize(n, 0);
    // CPU workload per model (zero for full-TPU models).
    let mut work = vec![0.0f64; n];
    let mut eligible: Vec<usize> = Vec::new();
    for i in 0..n {
        if partitions[i] < tenants[i].model.partition_points {
            // 1-core suffix service time × arrival rate = offered CPU load.
            work[i] = tenants[i].rate.max(1e-12) * cpu_service(i);
            eligible.push(i);
        }
    }
    if eligible.is_empty() || k_max == 0 {
        return;
    }
    if eligible.len() >= k_max {
        // Not enough cores for the floor: give one core each to the
        // heaviest-workload models.
        let mut order = eligible.clone();
        order.sort_by(|&a, &b| work[b].partial_cmp(&work[a]).unwrap());
        for &i in order.iter().take(k_max) {
            cores[i] = 1;
        }
        return;
    }
    // Floor of 1 core each; distribute the remainder proportionally.
    let total_work: f64 = eligible.iter().map(|&i| work[i]).sum();
    let spare = k_max - eligible.len();
    let mut shares: Vec<(usize, usize, f64)> = Vec::new(); // (idx, floor, remainder)
    let mut assigned = 0usize;
    for &i in &eligible {
        let frac = if total_work > 0.0 {
            work[i] / total_work * spare as f64
        } else {
            spare as f64 / eligible.len() as f64
        };
        let fl = frac.floor() as usize;
        shares.push((i, fl, frac - fl as f64));
        assigned += fl;
    }
    // Largest remainders get the leftover cores.
    shares.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
    let mut leftover = spare - assigned;
    for (idx, fl, _) in &shares {
        let extra = if leftover > 0 {
            leftover -= 1;
            1
        } else {
            0
        };
        cores[*idx] = 1 + fl + extra;
    }
}

/// Convenience: a full named allocation result.
#[derive(Debug, Clone)]
pub struct Allocation {
    pub config: Config,
    pub predicted_objective: f64,
    /// Number of candidate evaluations performed (decision-overhead metric).
    pub evaluations: usize,
}

/// Why a candidate tenant mix was refused admission: the analytic model
/// found no *stable* configuration — under every allocation the planner
/// could reach, some processor sits at ρ ≥ 1 and the predicted latency
/// diverges. Carries the best objective the planner saw so callers (and
/// operators) can report how far from feasible the mix is.
#[derive(Debug, Clone)]
pub struct AdmissionError {
    /// Objective (Eq. 5) of the best configuration found — infinite when
    /// every reachable configuration is unstable.
    pub predicted_objective: f64,
    /// TPU utilization ρ under that best-effort configuration.
    pub tpu_utilization: f64,
    /// Size of the rejected candidate mix.
    pub n_tenants: usize,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "admission rejected: no stable configuration for the {}-tenant mix \
             (best predicted objective {}, ρ(TPU) {:.2})",
            self.n_tenants, self.predicted_objective, self.tpu_utilization
        )
    }
}

impl std::error::Error for AdmissionError {}

/// Model-driven admission control: decide whether a candidate tenant mix
/// can be served at all, and if so return the plan to install.
///
/// Runs the hill climb first (the paper's allocator); if the greedy search
/// terminates on an unstable plateau, falls back to the cheap baselines
/// and — for small mixes — the exhaustive reference solver, so a mix is
/// only rejected when no reachable configuration is stable. On rejection
/// the returned [`AdmissionError`] carries the best predicted objective.
pub fn admit(
    am: &crate::analytic::AnalyticModel,
    tenants: &[Tenant],
    k_max: usize,
) -> Result<Allocation, AdmissionError> {
    let tables = PrefixTables::for_tenants(&am.cost, tenants);
    admit_with_tables(am, tenants, &tables, k_max)
}

/// [`admit`] over prebuilt per-tenant [`PrefixTables`] — the coordinator
/// extends its table set incrementally on attach and reuses it here.
pub fn admit_with_tables(
    am: &crate::analytic::AnalyticModel,
    tenants: &[Tenant],
    tables: &[PrefixTables],
    k_max: usize,
) -> Result<Allocation, AdmissionError> {
    let plan = hill_climb_with_tables(am, tenants, tables, k_max);
    if plan.predicted_objective.is_finite() {
        return Ok(plan);
    }
    // The greedy climb can (rarely) terminate on an infinite plateau even
    // when a stable configuration exists; consult cheaper/stronger solvers
    // before refusing the tenant.
    let mut best = plan;
    for candidate in [
        edge_tpu_compiler_with_tables(am, tenants, tables),
        threshold_partitioning_with_tables(am, tenants, tables, k_max, 0.10),
    ] {
        if candidate.predicted_objective < best.predicted_objective {
            best = candidate;
        }
    }
    if best.predicted_objective.is_finite() {
        return Ok(best);
    }
    if tenants.len() <= 4 {
        if let Some(exact) = exhaustive_best_with_tables(am, tenants, tables, k_max) {
            if exact.predicted_objective.is_finite() {
                return Ok(exact);
            }
            if exact.predicted_objective < best.predicted_objective {
                best = exact;
            }
        }
    }
    Err(AdmissionError {
        predicted_objective: best.predicted_objective,
        tpu_utilization: am.tpu_utilization(tenants, &best.config),
        n_tenants: tenants.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::Tenant;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;

    fn setup() -> (CostModel, Vec<Tenant>) {
        let cost = CostModel::new(HardwareSpec::default());
        let tenants = vec![
            Tenant {
                model: synthetic_model("heavy", 6, 2_000_000, 2_000_000_000),
                rate: 4.0,
            },
            Tenant {
                model: synthetic_model("light", 4, 500_000, 100_000_000),
                rate: 1.0,
            },
        ];
        (cost, tenants)
    }

    #[test]
    fn prop_alloc_respects_cap_and_floor() {
        let (cost, tenants) = setup();
        let cores = prop_alloc(&cost, &tenants, &[0, 0], 4);
        assert!(cores.iter().sum::<usize>() <= 4);
        assert!(cores[0] >= 1 && cores[1] >= 1);
        // heavier CPU workload gets more cores
        assert!(cores[0] > cores[1]);
    }

    #[test]
    fn prop_alloc_full_tpu_gets_zero() {
        let (cost, tenants) = setup();
        let cores = prop_alloc(&cost, &tenants, &[6, 0], 4);
        assert_eq!(cores[0], 0);
        assert!(cores[1] >= 1);
    }

    #[test]
    fn prop_alloc_distributes_all_cores() {
        let (cost, tenants) = setup();
        let cores = prop_alloc(&cost, &tenants, &[3, 2], 4);
        assert_eq!(cores.iter().sum::<usize>(), 4);
    }

    #[test]
    fn prop_alloc_more_models_than_cores() {
        let cost = CostModel::new(HardwareSpec::default());
        let tenants: Vec<Tenant> = (0..6)
            .map(|i| Tenant {
                model: synthetic_model(&format!("m{i}"), 3, 1_000_000, 500_000_000),
                rate: (i + 1) as f64,
            })
            .collect();
        let cores = prop_alloc(&cost, &tenants, &[0; 6], 4);
        assert_eq!(cores.iter().sum::<usize>(), 4);
        // the four highest-rate models get the cores
        assert_eq!(cores[0], 0);
        assert_eq!(cores[1], 0);
        assert!(cores[2..].iter().all(|&k| k == 1));
    }

    #[test]
    fn prop_alloc_zero_kmax() {
        let (cost, tenants) = setup();
        let cores = prop_alloc(&cost, &tenants, &[0, 0], 0);
        assert_eq!(cores, vec![0, 0]);
    }

    #[test]
    fn prop_alloc_tables_matches_naive() {
        // Table-backed PropAlloc sees bit-identical workloads, so the
        // core vectors must match exactly on every partition vector.
        let (cost, tenants) = setup();
        let tables = PrefixTables::for_tenants(&cost, &tenants);
        for parts in [[0, 0], [3, 2], [6, 0], [2, 4], [5, 3]] {
            for k_max in 0..=6 {
                assert_eq!(
                    prop_alloc(&cost, &tenants, &parts, k_max),
                    prop_alloc_tables(&tables, &tenants, &parts, k_max),
                    "parts {parts:?} k_max {k_max}"
                );
            }
        }
    }
}
