//! The analytic queueing model (Section III-B): M/G/1/FCFS TPU with
//! Pollaczek–Khinchine waiting (Eq. 1–2), M/D/k per-model CPU queues
//! (Eq. 3), end-to-end latency (Eq. 4), the weighted objective (Eq. 5),
//! and the weight-miss probability α (Eq. 10).
//!
//! All times are seconds; rates are requests/second. Unstable
//! configurations (ρ ≥ 1 on either processor) evaluate to `f64::INFINITY`,
//! which the allocator naturally avoids.

pub mod delta;

pub use delta::{objective_with_tables, DeltaEvaluator};

use crate::model::ModelMeta;
use crate::tpu::CostModel;

/// One co-located model with its arrival rate (`λ_{M_i}`).
#[derive(Debug, Clone)]
pub struct Tenant {
    pub model: ModelMeta,
    pub rate: f64,
}

/// Stable identity of an attached tenant.
///
/// Handles are allocated monotonically by the issuing engine (the live
/// [`coordinator::Server`](crate::coordinator::Server) or the DES
/// [`sim::Simulator`](crate::sim::Simulator)) and survive churn: detaching
/// a tenant never renumbers its peers, so statistics, caches, and
/// configuration vectors keyed by handle stay attributed to the right
/// tenant across attach/detach cycles. The *positional* index of a tenant
/// in a `Config`/`&[Tenant]` pair is transient and only meaningful for the
/// lifetime of one snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantHandle(pub u64);

impl std::fmt::Display for TenantHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tenant#{}", self.0)
    }
}

/// A global configuration: partition vector `P` and core vector `K`.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    pub partitions: Vec<usize>,
    pub cores: Vec<usize>,
}

impl Config {
    pub fn all_cpu(n: usize) -> Config {
        Config {
            partitions: vec![0; n],
            cores: vec![0; n],
        }
    }

    pub fn all_tpu(tenants: &[Tenant]) -> Config {
        Config {
            partitions: tenants.iter().map(|t| t.model.partition_points).collect(),
            cores: vec![0; tenants.len()],
        }
    }
}

/// Per-model latency breakdown (useful for validation figures).
#[derive(Debug, Clone, Default)]
pub struct LatencyBreakdown {
    pub input_transfer: f64,
    pub tpu_wait: f64,
    pub tpu_reload: f64,
    pub tpu_service: f64,
    pub output_transfer: f64,
    pub cpu_wait: f64,
    pub cpu_service: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        self.input_transfer
            + self.tpu_wait
            + self.tpu_reload
            + self.tpu_service
            + self.output_transfer
            + self.cpu_wait
            + self.cpu_service
    }
}

/// One-pass evaluation of a configuration (see [`AnalyticModel::evaluate`]).
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub alphas: Vec<f64>,
    pub tpu_rate: f64,
    pub tpu_utilization: f64,
    pub tpu_wait: f64,
    pub e2e: Vec<f64>,
    pub objective: f64,
}

/// How the weight-miss probability α is estimated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlphaMode {
    /// Eq. 10 — the paper's conservative bound: once the aggregate
    /// footprint overflows, ANY intervening request evicts yours.
    Conservative,
    /// Extension (EXPERIMENTS.md §Ablations): only models whose resident
    /// set cannot co-reside with yours (`r_i + r_j > C`) evict you, so
    /// α_i = Λ_conflict / (λ_i + Λ_conflict). Reduces the over-prediction
    /// Eq. 10 exhibits on mixed-size tenancies (small models co-residing
    /// between rare big-model arrivals) while degenerating to Eq. 10 in
    /// the all-conflicting two-model case.
    Pairwise,
    /// The paper's "SwapLess (α=0)" ablation baseline.
    Zero,
}

#[derive(Debug, Clone)]
pub struct AnalyticModel {
    pub cost: CostModel,
    pub alpha_mode: AlphaMode,
}

impl AnalyticModel {
    pub fn new(cost: CostModel) -> AnalyticModel {
        AnalyticModel {
            cost,
            alpha_mode: AlphaMode::Conservative,
        }
    }

    pub fn with_alpha_zero(cost: CostModel) -> AnalyticModel {
        AnalyticModel {
            cost,
            alpha_mode: AlphaMode::Zero,
        }
    }

    pub fn with_alpha_mode(cost: CostModel, mode: AlphaMode) -> AnalyticModel {
        AnalyticModel {
            cost,
            alpha_mode: mode,
        }
    }

    /// Aggregate TPU arrival rate `λ^TPU = Σ 1(p_i > 0) λ_i`.
    pub fn tpu_rate(&self, tenants: &[Tenant], cfg: &Config) -> f64 {
        tenants
            .iter()
            .zip(&cfg.partitions)
            .filter(|(_, p)| **p > 0)
            .map(|(t, _)| t.rate)
            .sum()
    }

    /// Weight-miss probability `α_{M_i}` (Eq. 10, or the pairwise refinement).
    pub fn alpha(&self, tenants: &[Tenant], cfg: &Config, i: usize) -> f64 {
        if self.alpha_mode == AlphaMode::Zero || cfg.partitions[i] == 0 || tenants[i].rate <= 0.0 {
            return 0.0;
        }
        let active: Vec<usize> = (0..tenants.len())
            .filter(|&j| cfg.partitions[j] > 0 && tenants[j].rate > 0.0)
            .collect();
        // Single-tenant regime: the driver keeps the resident set on-chip.
        if active.len() <= 1 {
            return 0.0;
        }
        // Aggregate footprint fits: steady state keeps everyone resident.
        let total_footprint: u64 = (0..tenants.len())
            .map(|j| self.cost.resident_bytes(&tenants[j].model, cfg.partitions[j]))
            .sum();
        if total_footprint <= self.cost.hw.sram_bytes {
            return 0.0;
        }
        match self.alpha_mode {
            AlphaMode::Conservative => {
                let lam_tpu = self.tpu_rate(tenants, cfg);
                if lam_tpu <= 0.0 {
                    return 0.0;
                }
                1.0 - tenants[i].rate / lam_tpu
            }
            AlphaMode::Pairwise => self.alpha_pairwise(tenants, cfg, i, &active),
            AlphaMode::Zero => unreachable!(),
        }
    }

    /// Pairwise-conflict α: only peers whose resident set cannot co-reside
    /// with model i's contribute to its eviction rate.
    fn alpha_pairwise(&self, tenants: &[Tenant], cfg: &Config, i: usize, active: &[usize]) -> f64 {
        let r_i = self.cost.resident_bytes(&tenants[i].model, cfg.partitions[i]);
        let mut conflict_rate = 0.0;
        for &j in active {
            if j == i {
                continue;
            }
            let r_j = self.cost.resident_bytes(&tenants[j].model, cfg.partitions[j]);
            if r_i + r_j > self.cost.hw.sram_bytes {
                conflict_rate += tenants[j].rate;
            }
        }
        if conflict_rate <= 0.0 {
            return 0.0;
        }
        conflict_rate / (tenants[i].rate + conflict_rate)
    }

    /// First and second moments of the TPU service-time mixture (Eq. 2).
    ///
    /// Per-request service for model i is `s_i + Bernoulli(α_i)·T_load,i`
    /// (deterministic compute+intra-swap plus a probabilistic reload), so
    ///   E[s]  = Σ (λi/λ) (αi·T + s)
    ///   E[s²] = Σ (λi/λ) (αi·(T+s)² + (1-αi)·s²)
    pub fn tpu_service_moments(&self, tenants: &[Tenant], cfg: &Config) -> (f64, f64) {
        let lam_tpu = self.tpu_rate(tenants, cfg);
        if lam_tpu <= 0.0 {
            return (0.0, 0.0);
        }
        let mut m1 = 0.0;
        let mut m2 = 0.0;
        for (i, t) in tenants.iter().enumerate() {
            let p = cfg.partitions[i];
            if p == 0 || t.rate <= 0.0 {
                continue;
            }
            let w = t.rate / lam_tpu;
            let s = self.cost.tpu_service(&t.model, p);
            let a = self.alpha(tenants, cfg, i);
            let tl = self.cost.load_time(&t.model, p);
            m1 += w * (a * tl + s);
            m2 += w * (a * (tl + s) * (tl + s) + (1.0 - a) * s * s);
        }
        (m1, m2)
    }

    /// TPU utilization `ρ^TPU = λ^TPU · E[s^TPU]`.
    pub fn tpu_utilization(&self, tenants: &[Tenant], cfg: &Config) -> f64 {
        let lam = self.tpu_rate(tenants, cfg);
        let (m1, _) = self.tpu_service_moments(tenants, cfg);
        lam * m1
    }

    /// `E[W^TPU]` — Pollaczek–Khinchine mean wait (Eq. 1).
    pub fn tpu_wait(&self, tenants: &[Tenant], cfg: &Config) -> f64 {
        let lam = self.tpu_rate(tenants, cfg);
        if lam <= 0.0 {
            return 0.0;
        }
        let (m1, m2) = self.tpu_service_moments(tenants, cfg);
        let rho = lam * m1;
        if rho >= 1.0 {
            return f64::INFINITY;
        }
        lam * m2 / (2.0 * (1.0 - rho))
    }

    /// `E[W^CPU_{M_i}]` — M/D/k approximation (Eq. 3).
    pub fn cpu_wait(&self, tenant: &Tenant, p: usize, k: usize) -> f64 {
        if p >= tenant.model.partition_points || tenant.rate <= 0.0 {
            return 0.0;
        }
        if k == 0 {
            return f64::INFINITY; // constraint (8) violated — no server
        }
        let s = self.cost.cpu_service(&tenant.model, p);
        let mu = 1.0 / s;
        let cap = k as f64 * mu;
        if tenant.rate >= cap {
            return f64::INFINITY;
        }
        0.5 * (1.0 / (cap - tenant.rate) - 1.0 / cap)
    }

    /// Full per-model latency breakdown under `(P, K)` (Eq. 4's terms).
    pub fn breakdown(&self, tenants: &[Tenant], cfg: &Config, i: usize) -> LatencyBreakdown {
        let t = &tenants[i];
        let p = cfg.partitions[i];
        let k = cfg.cores[i];
        let mut b = LatencyBreakdown::default();
        if p > 0 {
            b.input_transfer = self.cost.input_transfer(&t.model);
            b.tpu_wait = self.tpu_wait(tenants, cfg);
            b.tpu_reload =
                self.alpha(tenants, cfg, i) * self.cost.load_time(&t.model, p);
            b.tpu_service = self.cost.tpu_service(&t.model, p);
            b.output_transfer = self.cost.output_transfer(&t.model, p);
        }
        if p < t.model.partition_points {
            b.cpu_wait = self.cpu_wait(t, p, k);
            b.cpu_service = if k >= 1 {
                self.cost.cpu_service(&t.model, p)
            } else {
                f64::INFINITY
            };
        }
        b
    }

    /// `T^{e2e}_{M_i}(P, K)` (Eq. 4).
    pub fn e2e_latency(&self, tenants: &[Tenant], cfg: &Config, i: usize) -> f64 {
        self.breakdown(tenants, cfg, i).total()
    }

    /// Evaluate a whole configuration in one pass: α, the P-K wait, and
    /// every model's e2e latency share common subexpressions (aggregate
    /// rate, footprint, service moments), so computing them per-model —
    /// as the naive `objective()` did — costs O(n³) per evaluation. The
    /// hill climb calls this O(n·P) times per decision; this single-pass
    /// version is what keeps the allocator inside the paper's 2 ms budget
    /// (see EXPERIMENTS.md §Perf for before/after).
    pub fn evaluate(&self, tenants: &[Tenant], cfg: &Config) -> Evaluation {
        let n = tenants.len();
        // Pass 1: aggregate rate + footprint (α's regime inputs).
        let mut lam_tpu = 0.0;
        let mut footprint: u64 = 0;
        let mut active = 0usize;
        for (i, t) in tenants.iter().enumerate() {
            let p = cfg.partitions[i];
            footprint += self.cost.resident_bytes(&t.model, p);
            if p > 0 && t.rate > 0.0 {
                lam_tpu += t.rate;
                active += 1;
            }
        }
        let overflow = self.alpha_mode != AlphaMode::Zero
            && active > 1
            && footprint > self.cost.hw.sram_bytes;

        // Pass 2: α, per-model service terms, and the mixture moments.
        let mut alphas = vec![0.0f64; n];
        let mut m1 = 0.0;
        let mut m2 = 0.0;
        for (i, t) in tenants.iter().enumerate() {
            let p = cfg.partitions[i];
            if p == 0 || t.rate <= 0.0 {
                continue;
            }
            if overflow && lam_tpu > 0.0 {
                alphas[i] = match self.alpha_mode {
                    AlphaMode::Conservative => 1.0 - t.rate / lam_tpu,
                    AlphaMode::Pairwise => self.alpha(tenants, cfg, i),
                    AlphaMode::Zero => 0.0,
                };
            }
            let w = t.rate / lam_tpu;
            let s = self.cost.tpu_service(&t.model, p);
            let tl = self.cost.load_time(&t.model, p);
            let a = alphas[i];
            m1 += w * (a * tl + s);
            m2 += w * (a * (tl + s) * (tl + s) + (1.0 - a) * s * s);
        }
        let rho = lam_tpu * m1;
        let tpu_wait = if lam_tpu <= 0.0 {
            0.0
        } else if rho >= 1.0 {
            f64::INFINITY
        } else {
            lam_tpu * m2 / (2.0 * (1.0 - rho))
        };

        // Pass 3: per-model e2e (Eq. 4) and the weighted objective (Eq. 5).
        let mut e2e = vec![0.0f64; n];
        let mut objective = 0.0;
        for (i, t) in tenants.iter().enumerate() {
            let p = cfg.partitions[i];
            let k = cfg.cores[i];
            let mut total = 0.0;
            if p > 0 {
                total += self.cost.input_transfer(&t.model)
                    + tpu_wait
                    + alphas[i] * self.cost.load_time(&t.model, p)
                    + self.cost.tpu_service(&t.model, p)
                    + self.cost.output_transfer(&t.model, p);
            }
            if p < t.model.partition_points {
                total += self.cpu_wait(t, p, k);
                total += if k >= 1 {
                    self.cost.cpu_service(&t.model, p)
                } else {
                    f64::INFINITY
                };
            }
            e2e[i] = total;
            if t.rate > 0.0 {
                objective += t.rate * total; // guard: 0 * INF would be NaN
            }
        }

        Evaluation {
            alphas,
            tpu_rate: lam_tpu,
            tpu_utilization: rho,
            tpu_wait,
            e2e,
            objective,
        }
    }

    /// The optimization objective `Σ λ_i · T_i` (Eq. 5).
    ///
    /// Allocation-free specialization of [`evaluate`](Self::evaluate) —
    /// this is the innermost call of the hill climb (α is O(1) per model
    /// given the aggregate rate, so no scratch vectors are needed).
    pub fn objective(&self, tenants: &[Tenant], cfg: &Config) -> f64 {
        let mut lam_tpu = 0.0;
        let mut footprint: u64 = 0;
        let mut active = 0usize;
        for (i, t) in tenants.iter().enumerate() {
            let p = cfg.partitions[i];
            footprint += self.cost.resident_bytes(&t.model, p);
            if p > 0 && t.rate > 0.0 {
                lam_tpu += t.rate;
                active += 1;
            }
        }
        let overflow = self.alpha_mode != AlphaMode::Zero
            && active > 1
            && footprint > self.cost.hw.sram_bytes;
        if overflow && self.alpha_mode == AlphaMode::Pairwise {
            // pairwise α needs per-peer footprints — use the general path.
            return self.evaluate(tenants, cfg).objective;
        }
        let alpha_of = |t: &Tenant| -> f64 {
            if overflow {
                1.0 - t.rate / lam_tpu
            } else {
                0.0
            }
        };

        let mut m1 = 0.0;
        let mut m2 = 0.0;
        for (i, t) in tenants.iter().enumerate() {
            let p = cfg.partitions[i];
            if p == 0 || t.rate <= 0.0 {
                continue;
            }
            let w = t.rate / lam_tpu;
            let s = self.cost.tpu_service(&t.model, p);
            let tl = self.cost.load_time(&t.model, p);
            let a = alpha_of(t);
            m1 += w * (a * tl + s);
            m2 += w * (a * (tl + s) * (tl + s) + (1.0 - a) * s * s);
        }
        let rho = lam_tpu * m1;
        let tpu_wait = if lam_tpu <= 0.0 {
            0.0
        } else if rho >= 1.0 {
            return f64::INFINITY;
        } else {
            lam_tpu * m2 / (2.0 * (1.0 - rho))
        };

        let mut objective = 0.0;
        for (i, t) in tenants.iter().enumerate() {
            let p = cfg.partitions[i];
            let k = cfg.cores[i];
            let mut total = 0.0;
            if p > 0 && t.rate > 0.0 {
                total += self.cost.input_transfer(&t.model)
                    + tpu_wait
                    + alpha_of(t) * self.cost.load_time(&t.model, p)
                    + self.cost.tpu_service(&t.model, p)
                    + self.cost.output_transfer(&t.model, p);
            } else if p > 0 {
                // zero-rate models still contribute their (rate-weighted,
                // hence zero) term; skip the wait computation entirely.
                total += 0.0;
            }
            if p < t.model.partition_points {
                total += self.cpu_wait(t, p, k);
                total += if k >= 1 {
                    self.cost.cpu_service(&t.model, p)
                } else {
                    f64::INFINITY
                };
            }
            if t.rate > 0.0 {
                objective += t.rate * total; // guard: 0 * INF would be NaN
            }
        }
        objective
    }

    /// Per-request TPU service-time estimate for scheduling hints: the
    /// deterministic prefix compute + intra-partition swap under
    /// partition `p` — what the shortest-predicted-service-first
    /// discipline orders the shared TPU queue by, and what weighted-fair
    /// queueing charges against tenant deficits.
    pub fn tpu_service_hint(&self, model: &ModelMeta, p: usize) -> f64 {
        self.cost.tpu_service(model, p)
    }

    /// Per-request CPU suffix service-time estimate (segments [p, P)) —
    /// the scheduling hint for the per-tenant CPU stations.
    pub fn cpu_service_hint(&self, model: &ModelMeta, p: usize) -> f64 {
        self.cost.cpu_service(model, p)
    }

    /// O(1) admission-time wait estimate for a bounded station: the
    /// predicted service backlog already queued (the running sum of the
    /// prefix-table hints a `SchedQueue` maintains) divided across the
    /// station's parallel servers. This is the quantity a typed
    /// [`Overloaded`](crate::sched::Overloaded) rejection reports, so
    /// clients can convert backpressure into retry budgets.
    pub fn station_wait_estimate(&self, queued_service_s: f64, servers: usize) -> f64 {
        queued_service_s / servers.max(1) as f64
    }

    /// Request-weighted mean latency (what Fig. 7 plots).
    pub fn mean_latency(&self, tenants: &[Tenant], cfg: &Config) -> f64 {
        let lam: f64 = tenants.iter().map(|t| t.rate).sum();
        if lam <= 0.0 {
            return 0.0;
        }
        self.objective(tenants, cfg) / lam
    }
}

/// Validate a configuration against constraints (6)–(9).
pub fn check_constraints(
    tenants: &[Tenant],
    cfg: &Config,
    k_max: usize,
) -> Result<(), String> {
    if cfg.partitions.len() != tenants.len() || cfg.cores.len() != tenants.len() {
        return Err("config dimension mismatch".into());
    }
    let mut total_cores = 0;
    for (i, t) in tenants.iter().enumerate() {
        let p = cfg.partitions[i];
        let k = cfg.cores[i];
        if p > t.model.partition_points {
            return Err(format!("p_{i}={p} out of range (6)"));
        }
        if k > k_max {
            return Err(format!("k_{i}={k} out of range (7)"));
        }
        if p < t.model.partition_points && k < 1 {
            return Err(format!("model {i} has a CPU suffix but no cores (8)"));
        }
        if p == t.model.partition_points && k != 0 {
            return Err(format!("model {i} is full-TPU but holds cores (8)"));
        }
        total_cores += k;
    }
    if total_cores > k_max {
        return Err(format!("Σk = {total_cores} > K_max = {k_max} (9)"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;

    fn setup(n: usize) -> (AnalyticModel, Vec<Tenant>) {
        let cost = CostModel::new(HardwareSpec::default());
        let tenants: Vec<Tenant> = (0..n)
            .map(|i| Tenant {
                model: synthetic_model(&format!("m{i}"), 6, 2_000_000, 500_000_000),
                rate: 2.0,
            })
            .collect();
        (AnalyticModel::new(cost), tenants)
    }

    #[test]
    fn alpha_zero_when_fits() {
        // 2 models, prefix 1 segment each = 4 MB total < 8 MB.
        let (am, tenants) = setup(2);
        let cfg = Config {
            partitions: vec![1, 1],
            cores: vec![1, 1],
        };
        assert_eq!(am.alpha(&tenants, &cfg, 0), 0.0);
    }

    #[test]
    fn alpha_zero_single_tenant_even_when_oversized() {
        let (am, tenants) = setup(1);
        let cfg = Config {
            partitions: vec![6], // 12 MB > 8 MB
            cores: vec![0],
        };
        assert_eq!(am.alpha(&tenants, &cfg, 0), 0.0);
    }

    #[test]
    fn alpha_matches_rate_share_when_overflowing() {
        let (am, mut tenants) = setup(2);
        tenants[0].rate = 9.0;
        tenants[1].rate = 1.0;
        let cfg = Config {
            partitions: vec![4, 4], // 8 MB + 8 MB > 8 MB
            cores: vec![1, 1],
        };
        assert!((am.alpha(&tenants, &cfg, 0) - 0.1).abs() < 1e-12);
        assert!((am.alpha(&tenants, &cfg, 1) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn forced_alpha_zero() {
        let (mut am, mut tenants) = setup(2);
        am.alpha_mode = AlphaMode::Zero;
        tenants[0].rate = 5.0;
        let cfg = Config {
            partitions: vec![6, 6],
            cores: vec![0, 0],
        };
        assert_eq!(am.alpha(&tenants, &cfg, 0), 0.0);
    }

    #[test]
    fn pk_wait_grows_with_load_and_diverges() {
        let (am, mut tenants) = setup(1);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        tenants[0].rate = 1.0;
        let w1 = am.tpu_wait(&tenants, &cfg);
        tenants[0].rate = 5.0;
        let w5 = am.tpu_wait(&tenants, &cfg);
        assert!(w5 > w1 && w1 > 0.0);
        tenants[0].rate = 1e6;
        assert!(am.tpu_wait(&tenants, &cfg).is_infinite());
    }

    #[test]
    fn pk_matches_md1_for_deterministic_single_model() {
        // Single tenant, α=0 ⇒ deterministic service ⇒ M/D/1:
        // E[W] = ρ s / (2 (1-ρ)).
        let (am, mut tenants) = setup(1);
        tenants[0].rate = 3.0;
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let s = am.cost.tpu_service(&tenants[0].model, 6);
        let rho = 3.0 * s;
        let expect = rho * s / (2.0 * (1.0 - rho));
        let got = am.tpu_wait(&tenants, &cfg);
        assert!((got - expect).abs() < 1e-12, "got {got} expect {expect}");
    }

    #[test]
    fn cpu_wait_zero_load_and_divergence() {
        let (am, mut tenants) = setup(1);
        tenants[0].rate = 0.5;
        let w = am.cpu_wait(&tenants[0], 0, 2);
        assert!(w > 0.0 && w.is_finite());
        tenants[0].rate = 1e9;
        assert!(am.cpu_wait(&tenants[0], 0, 2).is_infinite());
        // no cores => infinite
        tenants[0].rate = 0.5;
        assert!(am.cpu_wait(&tenants[0], 0, 0).is_infinite());
    }

    #[test]
    fn cpu_wait_decreases_with_cores() {
        let (am, tenants) = setup(1);
        let w1 = am.cpu_wait(&tenants[0], 0, 1);
        let w4 = am.cpu_wait(&tenants[0], 0, 4);
        assert!(w4 < w1);
    }

    #[test]
    fn e2e_full_tpu_has_no_cpu_terms() {
        let (am, tenants) = setup(1);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let b = am.breakdown(&tenants, &cfg, 0);
        assert_eq!(b.cpu_wait, 0.0);
        assert_eq!(b.cpu_service, 0.0);
        assert!(b.tpu_service > 0.0);
        assert!(b.input_transfer > 0.0);
    }

    #[test]
    fn e2e_full_cpu_has_no_tpu_terms() {
        let (am, tenants) = setup(1);
        let cfg = Config {
            partitions: vec![0],
            cores: vec![2],
        };
        let b = am.breakdown(&tenants, &cfg, 0);
        assert_eq!(b.tpu_service, 0.0);
        assert_eq!(b.input_transfer, 0.0);
        assert!(b.cpu_service > 0.0);
    }

    #[test]
    fn service_hints_match_cost_model() {
        // The scheduling hints are thin, documented views of the cost
        // model (the prefix tables consumed on the hot paths are pinned
        // bit-exact against the same quantities elsewhere).
        let (am, tenants) = setup(1);
        let m = &tenants[0].model;
        for p in 0..=m.partition_points {
            assert_eq!(am.tpu_service_hint(m, p), am.cost.tpu_service(m, p));
            assert_eq!(am.cpu_service_hint(m, p), am.cost.cpu_service(m, p));
        }
        assert_eq!(am.tpu_service_hint(m, 0), 0.0);
        assert_eq!(am.cpu_service_hint(m, m.partition_points), 0.0);
    }

    #[test]
    fn station_wait_estimate_divides_backlog_across_servers() {
        let (am, _) = setup(1);
        assert_eq!(am.station_wait_estimate(0.060, 1), 0.060);
        assert_eq!(am.station_wait_estimate(0.060, 3), 0.020);
        // Degenerate server counts never divide by zero.
        assert_eq!(am.station_wait_estimate(0.060, 0), 0.060);
        assert_eq!(am.station_wait_estimate(0.0, 4), 0.0);
    }

    #[test]
    fn objective_weights_by_rate() {
        let (am, mut tenants) = setup(2);
        tenants[1].rate = 0.0;
        let cfg = Config {
            partitions: vec![6, 6],
            cores: vec![0, 0],
        };
        let obj = am.objective(&tenants, &cfg);
        let t0 = am.e2e_latency(&tenants, &cfg, 0);
        assert!((obj - 2.0 * t0).abs() < 1e-12);
    }

    #[test]
    fn constraints_checker() {
        let (_, tenants) = setup(2);
        let ok = Config {
            partitions: vec![6, 3],
            cores: vec![0, 2],
        };
        check_constraints(&tenants, &ok, 4).unwrap();
        let bad_p = Config {
            partitions: vec![7, 3],
            cores: vec![0, 2],
        };
        assert!(check_constraints(&tenants, &bad_p, 4).is_err());
        let bad_k = Config {
            partitions: vec![3, 3],
            cores: vec![0, 2],
        };
        assert!(check_constraints(&tenants, &bad_k, 4).is_err());
        let over_k = Config {
            partitions: vec![3, 3],
            cores: vec![3, 3],
        };
        assert!(check_constraints(&tenants, &over_k, 4).is_err());
        let full_tpu_with_cores = Config {
            partitions: vec![6, 6],
            cores: vec![1, 0],
        };
        assert!(check_constraints(&tenants, &full_tpu_with_cores, 4).is_err());
    }

    #[test]
    fn evaluate_matches_per_call_apis() {
        // The fused one-pass evaluation must agree exactly with the
        // formula-by-formula methods it optimizes over.
        let (am, mut tenants) = setup(3);
        tenants[0].rate = 4.0;
        tenants[2].rate = 0.5;
        for cfg in [
            Config {
                partitions: vec![6, 3, 0],
                cores: vec![0, 2, 2],
            },
            Config {
                partitions: vec![6, 6, 6],
                cores: vec![0, 0, 0],
            },
            Config {
                partitions: vec![0, 0, 0],
                cores: vec![2, 1, 1],
            },
        ] {
            let ev = am.evaluate(&tenants, &cfg);
            let direct_wait = am.tpu_wait(&tenants, &cfg);
            assert!(
                (ev.tpu_wait - direct_wait).abs() < 1e-12
                    || (ev.tpu_wait.is_infinite() && direct_wait.is_infinite())
            );
            assert!((ev.tpu_rate - am.tpu_rate(&tenants, &cfg)).abs() < 1e-12);
            for i in 0..3 {
                assert!(
                    (ev.alphas[i] - am.alpha(&tenants, &cfg, i)).abs() < 1e-12,
                    "alpha {i}"
                );
                let direct = am.e2e_latency(&tenants, &cfg, i);
                if direct.is_finite() {
                    assert!((ev.e2e[i] - direct).abs() < 1e-12, "e2e {i}");
                } else {
                    assert!(ev.e2e[i].is_infinite());
                }
            }
        }
    }

    #[test]
    fn pairwise_alpha_degenerates_to_eq10_for_two_conflicting_models() {
        let cost = CostModel::new(HardwareSpec::default());
        let cons = AnalyticModel::new(cost.clone());
        let pair = AnalyticModel::with_alpha_mode(cost, AlphaMode::Pairwise);
        let mut tenants: Vec<Tenant> = (0..2)
            .map(|i| Tenant {
                model: synthetic_model(&format!("m{i}"), 6, 1_200_000, 300_000_000),
                rate: 1.0,
            })
            .collect();
        tenants[0].rate = 3.0;
        let cfg = Config {
            partitions: vec![6, 6], // 7.2 MB each, both conflict
            cores: vec![0, 0],
        };
        for i in 0..2 {
            assert!(
                (cons.alpha(&tenants, &cfg, i) - pair.alpha(&tenants, &cfg, i)).abs() < 1e-12,
                "model {i}"
            );
        }
    }

    #[test]
    fn pairwise_alpha_spares_coresident_small_models() {
        // small+small+big: the two small models fit together; only the big
        // one evicts them. Pairwise α for a small model counts only the
        // big model's rate; Eq. 10 counts everything.
        let cost = CostModel::new(HardwareSpec::default());
        let cons = AnalyticModel::new(cost.clone());
        let pair = AnalyticModel::with_alpha_mode(cost, AlphaMode::Pairwise);
        let tenants = vec![
            Tenant {
                model: synthetic_model("small_a", 4, 500_000, 100_000_000), // 2 MB
                rate: 4.0,
            },
            Tenant {
                model: synthetic_model("small_b", 4, 500_000, 100_000_000), // 2 MB
                rate: 4.0,
            },
            Tenant {
                model: synthetic_model("big", 6, 1_400_000, 500_000_000), // 8.4 MB -> resident 8 MB
                rate: 0.5,
            },
        ];
        let cfg = Config {
            partitions: vec![4, 4, 6],
            cores: vec![0, 0, 0],
        };
        let a_cons = cons.alpha(&tenants, &cfg, 0);
        let a_pair = pair.alpha(&tenants, &cfg, 0);
        assert!(a_pair < a_cons, "pairwise {a_pair} !< conservative {a_cons}");
        // small_a is only evicted by big: α = 0.5 / (4 + 0.5)
        assert!((a_pair - 0.5 / 4.5).abs() < 1e-12);
        // the big model conflicts with everyone -> pairwise == Eq. 10
        assert!(
            (pair.alpha(&tenants, &cfg, 2) - cons.alpha(&tenants, &cfg, 2)).abs() < 1e-9
        );
    }

    #[test]
    fn intermodel_swapping_raises_latency() {
        // Two big prefixes that cannot co-reside: SwapLess-with-α must
        // predict higher latency than the α=0 ablation.
        let cost = CostModel::new(HardwareSpec::default());
        let tenants: Vec<Tenant> = (0..2)
            .map(|i| Tenant {
                model: synthetic_model(&format!("m{i}"), 6, 2_000_000, 500_000_000),
                rate: 1.0,
            })
            .collect();
        let with_alpha = AnalyticModel::new(cost.clone());
        let no_alpha = AnalyticModel::with_alpha_zero(cost);
        let cfg = Config {
            partitions: vec![6, 6],
            cores: vec![0, 0],
        };
        assert!(
            with_alpha.mean_latency(&tenants, &cfg) > no_alpha.mean_latency(&tenants, &cfg)
        );
    }
}
