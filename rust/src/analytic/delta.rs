//! Incremental (delta) evaluation of the allocator objective.
//!
//! [`AnalyticModel::objective`] recomputes every aggregate of Eq. 1–5 —
//! λ^TPU, the SRAM footprint, the mixture moments, each model's CPU-queue
//! terms — from scratch, iterating every tenant's segment list on the
//! way. The hill climb scores O(n·P) single-tenant candidate moves per
//! decision, and consecutive candidates differ in ONE tenant's partition
//! (plus whatever cores `PropAlloc` shuffles), so almost all of that work
//! is recomputation of unchanged state.
//!
//! [`DeltaEvaluator`] caches, per tenant, the O(1) cost terms (from
//! [`PrefixTables`]) and, globally, the rate-weighted sums the objective
//! is assembled from. Scoring a move `(m, p → p')` then costs O(1) for
//! the TPU-side mixture (plus O(#core-changes) for the CPU queues) in the
//! `Conservative`/`Zero` α modes. The trick for `Conservative` is that
//! Eq. 10's α_i = 1 − λ_i/λ makes every α-weighted sum expressible in
//! rate-only sums:
//!
//! ```text
//!   Σ λᵢ αᵢ xᵢ  =  Σ λᵢ xᵢ − (Σ λᵢ² xᵢ)/λ        (x ∈ {T_load, u})
//! ```
//!
//! so the evaluator maintains both Σλx and Σλ²x and never needs a
//! per-tenant α refresh — not even when the overflow regime flips or λ^TPU
//! changes (the O(n) refresh the naive formulation would need). The
//! `Pairwise` α mode depends on the conflict graph, so overflow-regime
//! moves there cost O(n) (still segment-free; see `pairwise_sums`).
//!
//! Numerical contract: a fresh build and a `score_move` agree with the
//! naive `objective()` to ≤1e-9 relative (property-tested over randomized
//! mixes in `tests/property_tests.rs`); `commit` rebuilds the cached
//! state from scratch (O(n), table-backed) so rounding drift can never
//! accumulate across a climb.

use crate::analytic::{AlphaMode, AnalyticModel, Config, Tenant};
use crate::tpu::PrefixTables;

/// Per-tenant cached contribution under the committed `(p, k)`.
#[derive(Debug, Clone, Copy, Default)]
struct Term {
    /// `p > 0 && λ > 0` — contributes to the TPU mixture.
    active: bool,
    /// Resident SRAM bytes of the prefix.
    resident: u64,
    /// `s^TPU(p)`.
    s: f64,
    /// `T_load(p)`.
    tl: f64,
    /// `(T_load + s)² − s²` — the α-weighted part of the second moment.
    u: f64,
    /// λ·(d_in/B + s^TPU + d_out/B) — the α-free TPU latency terms.
    loc: f64,
    /// λ·(E[W^CPU] + s^CPU) when finite, else 0 (see `cpu_inf`).
    cpu: f64,
    /// CPU side diverges (no core, or λ ≥ k·μ).
    cpu_inf: bool,
    /// Contribution to the hill climb's starvation measure.
    starve: usize,
}

/// Incremental objective/score evaluator over one tenant mix.
///
/// Construction is O(n) given prebuilt [`PrefixTables`]; `score_move` is
/// O(1) + O(#core-changes) (O(n) in `Pairwise` overflow); `commit` is a
/// full O(n) rebuild. Shared immutably across threads by the parallel
/// candidate scan (`&self` methods only).
#[derive(Debug, Clone)]
pub struct DeltaEvaluator<'a> {
    am: &'a AnalyticModel,
    tenants: &'a [Tenant],
    tables: &'a [PrefixTables],
    partitions: Vec<usize>,
    cores: Vec<usize>,
    terms: Vec<Term>,
    /// λ^TPU = Σ active λᵢ.
    lam: f64,
    /// Σ resident bytes over ALL tenants (α's regime input).
    footprint: u64,
    /// Number of active (p>0, λ>0) tenants.
    active: usize,
    /// Σ λ s, Σ λ s² over active tenants.
    s1: f64,
    s2: f64,
    /// Σ λ T_load, Σ λ² T_load over active tenants.
    t1: f64,
    t2: f64,
    /// Σ λ u, Σ λ² u over active tenants.
    u1: f64,
    u2: f64,
    /// Σ loc over active tenants.
    l1: f64,
    /// Σ finite CPU contributions; count of divergent ones.
    cpu_sum: f64,
    cpu_inf: usize,
    /// Starvation measure (suffix layers of core-less models).
    starvation: usize,
    /// Pairwise mode: Σ conflicting peer rates per tenant.
    conflict: Vec<f64>,
}

impl<'a> DeltaEvaluator<'a> {
    pub fn new(
        am: &'a AnalyticModel,
        tenants: &'a [Tenant],
        tables: &'a [PrefixTables],
        cfg: &Config,
    ) -> DeltaEvaluator<'a> {
        assert_eq!(tenants.len(), tables.len(), "one table per tenant");
        assert_eq!(cfg.partitions.len(), tenants.len());
        assert_eq!(cfg.cores.len(), tenants.len());
        let mut ev = DeltaEvaluator {
            am,
            tenants,
            tables,
            partitions: cfg.partitions.clone(),
            cores: cfg.cores.clone(),
            terms: Vec::new(),
            lam: 0.0,
            footprint: 0,
            active: 0,
            s1: 0.0,
            s2: 0.0,
            t1: 0.0,
            t2: 0.0,
            u1: 0.0,
            u2: 0.0,
            l1: 0.0,
            cpu_sum: 0.0,
            cpu_inf: 0,
            starvation: 0,
            conflict: Vec::new(),
        };
        ev.rebuild();
        ev
    }

    /// The committed configuration.
    pub fn config(&self) -> Config {
        Config {
            partitions: self.partitions.clone(),
            cores: self.cores.clone(),
        }
    }

    /// Recompute one tenant's cached term for `(p, k)` — O(1).
    fn term(&self, i: usize, p: usize, k: usize) -> Term {
        let rate = self.tenants[i].rate;
        let tab = &self.tables[i];
        let pp = tab.partition_points;
        let active = p > 0 && rate > 0.0;
        let s = tab.tpu_service(p);
        let tl = tab.load_time(p);
        let mut t = Term {
            active,
            resident: tab.resident_bytes(p),
            s,
            tl,
            u: (tl + s) * (tl + s) - s * s,
            loc: if active {
                rate * (tab.input_transfer() + s + tab.output_transfer(p))
            } else {
                0.0
            },
            cpu: 0.0,
            cpu_inf: false,
            starve: if p < pp && k == 0 { pp - p } else { 0 },
        };
        if rate > 0.0 && p < pp {
            // Mirrors AnalyticModel::cpu_wait + the k==0 ⇒ ∞ service rule.
            if k == 0 {
                t.cpu_inf = true;
            } else {
                let cs = tab.cpu_service(p);
                let mu = 1.0 / cs;
                let cap = k as f64 * mu;
                if rate >= cap {
                    t.cpu_inf = true;
                } else {
                    let wait = 0.5 * (1.0 / (cap - rate) - 1.0 / cap);
                    t.cpu = rate * (wait + cs);
                }
            }
        }
        t
    }

    /// Full O(n) rebuild of the cached aggregates (used by `new` and
    /// `commit` — keeps rounding drift from accumulating across moves).
    fn rebuild(&mut self) {
        let n = self.tenants.len();
        self.terms = (0..n)
            .map(|i| self.term(i, self.partitions[i], self.cores[i]))
            .collect();
        self.lam = 0.0;
        self.footprint = 0;
        self.active = 0;
        self.s1 = 0.0;
        self.s2 = 0.0;
        self.t1 = 0.0;
        self.t2 = 0.0;
        self.u1 = 0.0;
        self.u2 = 0.0;
        self.l1 = 0.0;
        self.cpu_sum = 0.0;
        self.cpu_inf = 0;
        self.starvation = 0;
        for (i, t) in self.terms.iter().enumerate() {
            let rate = self.tenants[i].rate;
            self.footprint += t.resident;
            if t.active {
                self.lam += rate;
                self.active += 1;
                self.s1 += rate * t.s;
                self.s2 += rate * t.s * t.s;
                self.t1 += rate * t.tl;
                self.t2 += rate * rate * t.tl;
                self.u1 += rate * t.u;
                self.u2 += rate * rate * t.u;
                self.l1 += t.loc;
            }
            self.cpu_sum += t.cpu;
            self.cpu_inf += t.cpu_inf as usize;
            self.starvation += t.starve;
        }
        self.conflict = vec![0.0; n];
        if self.am.alpha_mode == AlphaMode::Pairwise {
            let sram = self.am.cost.hw.sram_bytes;
            for i in 0..n {
                if !self.terms[i].active {
                    continue;
                }
                let mut c = 0.0;
                for j in 0..n {
                    if j != i
                        && self.terms[j].active
                        && self.terms[i].resident + self.terms[j].resident > sram
                    {
                        c += self.tenants[j].rate;
                    }
                }
                self.conflict[i] = c;
            }
        }
    }

    /// Pairwise-α sums `(Σ λ α T_load, Σ λ α u)`, optionally with tenant
    /// `m`'s term replaced by `moved` — O(n), segment-free.
    fn pairwise_sums(&self, moved: Option<(usize, &Term, f64)>) -> (f64, f64) {
        let sram = self.am.cost.hw.sram_bytes;
        let mut a1 = 0.0;
        let mut a2 = 0.0;
        for j in 0..self.tenants.len() {
            let rate = self.tenants[j].rate;
            let (t, c) = match moved {
                Some((m, new_term, new_conflict)) if j == m => (new_term, new_conflict),
                Some((m, new_term, _)) => {
                    let t = &self.terms[j];
                    let old_m = &self.terms[m];
                    let m_rate = self.tenants[m].rate;
                    let mut c = self.conflict[j];
                    if old_m.active && t.active && t.resident + old_m.resident > sram {
                        c -= m_rate;
                    }
                    if new_term.active && t.active && t.resident + new_term.resident > sram {
                        c += m_rate;
                    }
                    (t, c)
                }
                None => (&self.terms[j], self.conflict[j]),
            };
            if !t.active {
                continue;
            }
            let a = if c > 0.0 { c / (rate + c) } else { 0.0 };
            a1 += rate * a * t.tl;
            a2 += rate * a * t.u;
        }
        (a1, a2)
    }

    /// Assemble the objective from aggregate sums — O(1).
    ///
    /// `pair` carries the precomputed pairwise-α sums (only consulted in
    /// `Pairwise` mode under overflow).
    #[allow(clippy::too_many_arguments)]
    fn combine(
        &self,
        lam: f64,
        footprint: u64,
        active: usize,
        s1: f64,
        s2: f64,
        t1: f64,
        t2: f64,
        u1: f64,
        u2: f64,
        l1: f64,
        cpu_sum: f64,
        cpu_inf: usize,
        pair: Option<(f64, f64)>,
    ) -> f64 {
        if cpu_inf > 0 {
            return f64::INFINITY;
        }
        let overflow = self.am.alpha_mode != AlphaMode::Zero
            && active > 1
            && footprint > self.am.cost.hw.sram_bytes;
        // Σ λ α T_load and Σ λ α u under the current α mode/regime.
        let (a1, a2) = if !overflow {
            (0.0, 0.0)
        } else if self.am.alpha_mode == AlphaMode::Pairwise {
            pair.expect("pairwise sums required under overflow")
        } else {
            // Conservative closed form (see module docs).
            (t1 - t2 / lam, u1 - u2 / lam)
        };
        let lam_m1 = s1 + a1; // = λ·E[s] = ρ
        let lam_m2 = s2 + a2; // = λ·E[s²]
        let rho = lam_m1;
        let wait_term = if lam <= 0.0 {
            0.0
        } else if rho >= 1.0 {
            return f64::INFINITY;
        } else {
            // λ^TPU · E[W^TPU]: every TPU-bound request pays the P-K wait.
            lam * lam_m2 / (2.0 * (1.0 - rho))
        };
        wait_term + l1 + a1 + cpu_sum
    }

    /// The committed configuration's objective (Eq. 5) — O(1), O(n) in
    /// `Pairwise` mode under overflow.
    pub fn objective(&self) -> f64 {
        // Same overflow gate as `combine` so the O(n) conflict sweep only
        // runs when α is actually nonzero (mirrors `score_move`).
        let pair = if self.am.alpha_mode == AlphaMode::Pairwise {
            if self.active > 1 && self.footprint > self.am.cost.hw.sram_bytes {
                Some(self.pairwise_sums(None))
            } else {
                Some((0.0, 0.0))
            }
        } else {
            None
        };
        self.combine(
            self.lam,
            self.footprint,
            self.active,
            self.s1,
            self.s2,
            self.t1,
            self.t2,
            self.u1,
            self.u2,
            self.l1,
            self.cpu_sum,
            self.cpu_inf,
            pair,
        )
    }

    /// The hill climb's lexicographic score of the committed config.
    pub fn score(&self) -> (usize, f64) {
        (self.starvation, self.objective())
    }

    /// Score the candidate `(partitions[m] → new_p, cores → new_cores)`
    /// WITHOUT mutating the committed state. Cost: O(1) TPU-side + O(1)
    /// per changed core entry (O(n) total in `Pairwise` overflow).
    pub fn score_move(&self, m: usize, new_p: usize, new_cores: &[usize]) -> (usize, f64) {
        let rate = self.tenants[m].rate;
        let old = self.terms[m];
        let new = self.term(m, new_p, new_cores[m]);

        let mut lam = self.lam;
        let mut active = self.active;
        if old.active != new.active {
            if new.active {
                lam += rate;
                active += 1;
            } else {
                lam -= rate;
                active -= 1;
            }
        }
        let footprint = self.footprint - old.resident + new.resident;

        let mut s1 = self.s1;
        let mut s2 = self.s2;
        let mut t1 = self.t1;
        let mut t2 = self.t2;
        let mut u1 = self.u1;
        let mut u2 = self.u2;
        let mut l1 = self.l1;
        if old.active {
            s1 -= rate * old.s;
            s2 -= rate * old.s * old.s;
            t1 -= rate * old.tl;
            t2 -= rate * rate * old.tl;
            u1 -= rate * old.u;
            u2 -= rate * rate * old.u;
            l1 -= old.loc;
        }
        if new.active {
            s1 += rate * new.s;
            s2 += rate * new.s * new.s;
            t1 += rate * new.tl;
            t2 += rate * rate * new.tl;
            u1 += rate * new.u;
            u2 += rate * rate * new.u;
            l1 += new.loc;
        }

        let mut cpu_sum = self.cpu_sum + new.cpu - old.cpu;
        let mut cpu_inf = self.cpu_inf as i64 + new.cpu_inf as i64 - old.cpu_inf as i64;
        let mut starvation = self.starvation as i64 + new.starve as i64 - old.starve as i64;
        // Only tenants whose core share PropAlloc actually changed need a
        // CPU-queue refresh.
        for j in 0..self.tenants.len() {
            if j == m || new_cores[j] == self.cores[j] {
                continue;
            }
            let oldt = &self.terms[j];
            let newt = self.term(j, self.partitions[j], new_cores[j]);
            cpu_sum += newt.cpu - oldt.cpu;
            cpu_inf += newt.cpu_inf as i64 - oldt.cpu_inf as i64;
            starvation += newt.starve as i64 - oldt.starve as i64;
        }

        let pair = if self.am.alpha_mode == AlphaMode::Pairwise {
            let overflow = active > 1 && footprint > self.am.cost.hw.sram_bytes;
            if overflow {
                let sram = self.am.cost.hw.sram_bytes;
                let mut new_conflict = 0.0;
                if new.active {
                    for j in 0..self.tenants.len() {
                        if j != m
                            && self.terms[j].active
                            && new.resident + self.terms[j].resident > sram
                        {
                            new_conflict += self.tenants[j].rate;
                        }
                    }
                }
                Some(self.pairwise_sums(Some((m, &new, new_conflict))))
            } else {
                Some((0.0, 0.0))
            }
        } else {
            None
        };

        let obj = self.combine(
            lam,
            footprint,
            active,
            s1,
            s2,
            t1,
            t2,
            u1,
            u2,
            l1,
            cpu_sum,
            cpu_inf.max(0) as usize,
            pair,
        );
        (starvation.max(0) as usize, obj)
    }

    /// Commit a move: apply it and rebuild the cached state from scratch
    /// (O(n); anchors the incremental path to fresh-build rounding).
    pub fn commit(&mut self, m: usize, new_p: usize, new_cores: &[usize]) {
        self.partitions[m] = new_p;
        self.cores.clear();
        self.cores.extend_from_slice(new_cores);
        self.rebuild();
    }
}

/// `E[W^CPU]` (Eq. 3) via table lookups — mirrors
/// [`AnalyticModel::cpu_wait`] operation-for-operation.
fn cpu_wait_tables(tab: &PrefixTables, rate: f64, p: usize, k: usize) -> f64 {
    if p >= tab.partition_points || rate <= 0.0 {
        return 0.0;
    }
    if k == 0 {
        return f64::INFINITY;
    }
    let s = tab.cpu_service(p);
    let mu = 1.0 / s;
    let cap = k as f64 * mu;
    if rate >= cap {
        return f64::INFINITY;
    }
    0.5 * (1.0 / (cap - rate) - 1.0 / cap)
}

/// One-shot objective of `cfg` via prefix tables — the segment-free
/// replacement for `AnalyticModel::objective` used by the exhaustive
/// solver and the baselines.
///
/// Allocation-free on purpose: the exhaustive solver calls this at every
/// enumerated leaf, so it mirrors the naive `objective()` pass structure
/// directly (same operation order — bit-identical in `Conservative`/
/// `Zero` modes given the tables' bit-exactness) with O(1) table lookups
/// in place of the O(L) segment sums. Pairwise α costs O(n) per active
/// tenant, as in the naive path.
pub fn objective_with_tables(
    am: &AnalyticModel,
    tenants: &[Tenant],
    tables: &[PrefixTables],
    cfg: &Config,
) -> f64 {
    debug_assert_eq!(tenants.len(), tables.len());
    let sram = am.cost.hw.sram_bytes;
    // Pass 1: aggregate rate + footprint (α's regime inputs).
    let mut lam_tpu = 0.0;
    let mut footprint: u64 = 0;
    let mut active = 0usize;
    for (i, t) in tenants.iter().enumerate() {
        let p = cfg.partitions[i];
        footprint += tables[i].resident_bytes(p);
        if p > 0 && t.rate > 0.0 {
            lam_tpu += t.rate;
            active += 1;
        }
    }
    let overflow =
        am.alpha_mode != AlphaMode::Zero && active > 1 && footprint > sram;

    // α for tenant i under the current regime (only queried for active
    // tenants; O(1), O(n) in Pairwise mode).
    let alpha_of = |i: usize| -> f64 {
        if !overflow {
            return 0.0;
        }
        match am.alpha_mode {
            AlphaMode::Conservative => 1.0 - tenants[i].rate / lam_tpu,
            AlphaMode::Pairwise => {
                let r_i = tables[i].resident_bytes(cfg.partitions[i]);
                let mut conflict = 0.0;
                for (j, tj) in tenants.iter().enumerate() {
                    if j == i || cfg.partitions[j] == 0 || tj.rate <= 0.0 {
                        continue;
                    }
                    let r_j = tables[j].resident_bytes(cfg.partitions[j]);
                    if r_i + r_j > sram {
                        conflict += tj.rate;
                    }
                }
                if conflict <= 0.0 {
                    0.0
                } else {
                    conflict / (tenants[i].rate + conflict)
                }
            }
            AlphaMode::Zero => 0.0,
        }
    };

    // Pass 2: mixture moments (Eq. 2).
    let mut m1 = 0.0;
    let mut m2 = 0.0;
    for (i, t) in tenants.iter().enumerate() {
        let p = cfg.partitions[i];
        if p == 0 || t.rate <= 0.0 {
            continue;
        }
        let w = t.rate / lam_tpu;
        let s = tables[i].tpu_service(p);
        let tl = tables[i].load_time(p);
        let a = alpha_of(i);
        m1 += w * (a * tl + s);
        m2 += w * (a * (tl + s) * (tl + s) + (1.0 - a) * s * s);
    }
    let rho = lam_tpu * m1;
    let tpu_wait = if lam_tpu <= 0.0 {
        0.0
    } else if rho >= 1.0 {
        return f64::INFINITY;
    } else {
        lam_tpu * m2 / (2.0 * (1.0 - rho))
    };

    // Pass 3: per-model e2e terms and the weighted objective (Eq. 4–5).
    let mut objective = 0.0;
    for (i, t) in tenants.iter().enumerate() {
        let p = cfg.partitions[i];
        let k = cfg.cores[i];
        let tab = &tables[i];
        let mut total = 0.0;
        if p > 0 && t.rate > 0.0 {
            total += tab.input_transfer()
                + tpu_wait
                + alpha_of(i) * tab.load_time(p)
                + tab.tpu_service(p)
                + tab.output_transfer(p);
        }
        if p < tab.partition_points {
            total += cpu_wait_tables(tab, t.rate, p, k);
            total += if k >= 1 {
                tab.cpu_service(p)
            } else {
                f64::INFINITY
            };
        }
        if t.rate > 0.0 {
            objective += t.rate * total; // guard: 0 * INF would be NaN
        }
    }
    objective
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::tpu::CostModel;

    fn setup(mode: AlphaMode) -> (AnalyticModel, Vec<Tenant>) {
        let cost = CostModel::new(HardwareSpec::default());
        let tenants: Vec<Tenant> = (0..3)
            .map(|i| Tenant {
                model: synthetic_model(&format!("m{i}"), 6, 2_000_000, 500_000_000),
                rate: 1.0 + i as f64,
            })
            .collect();
        (AnalyticModel::with_alpha_mode(cost, mode), tenants)
    }

    fn agree(a: f64, b: f64) -> bool {
        if a.is_infinite() || b.is_infinite() {
            return a.is_infinite() && b.is_infinite();
        }
        (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
    }

    #[test]
    fn matches_naive_objective_across_modes() {
        for mode in [AlphaMode::Conservative, AlphaMode::Pairwise, AlphaMode::Zero] {
            let (am, tenants) = setup(mode);
            let tables = PrefixTables::for_tenants(&am.cost, &tenants);
            for cfg in [
                Config {
                    partitions: vec![6, 3, 0],
                    cores: vec![0, 2, 2],
                },
                Config {
                    partitions: vec![6, 6, 6],
                    cores: vec![0, 0, 0],
                },
                Config {
                    partitions: vec![0, 0, 0],
                    cores: vec![2, 1, 1],
                },
                Config {
                    partitions: vec![4, 4, 4],
                    cores: vec![1, 1, 1],
                },
            ] {
                let ev = DeltaEvaluator::new(&am, &tenants, &tables, &cfg);
                let naive = am.objective(&tenants, &cfg);
                assert!(
                    agree(ev.objective(), naive),
                    "{mode:?} {cfg:?}: delta {} vs naive {naive}",
                    ev.objective()
                );
            }
        }
    }

    #[test]
    fn score_move_matches_fresh_build() {
        for mode in [AlphaMode::Conservative, AlphaMode::Pairwise, AlphaMode::Zero] {
            let (am, tenants) = setup(mode);
            let tables = PrefixTables::for_tenants(&am.cost, &tenants);
            let cfg = Config {
                partitions: vec![2, 4, 0],
                cores: vec![1, 1, 2],
            };
            let ev = DeltaEvaluator::new(&am, &tenants, &tables, &cfg);
            for (m, new_p, new_cores) in [
                (0usize, 4usize, vec![1usize, 1, 2]),
                (2, 3, vec![1, 1, 2]),
                (1, 6, vec![2, 0, 2]),
                (0, 0, vec![2, 1, 1]),
            ] {
                let (_, got) = ev.score_move(m, new_p, &new_cores);
                let mut moved = cfg.clone();
                moved.partitions[m] = new_p;
                moved.cores = new_cores.clone();
                let fresh = DeltaEvaluator::new(&am, &tenants, &tables, &moved);
                assert!(
                    agree(got, fresh.objective()),
                    "{mode:?} move m={m} p={new_p}: {} vs {}",
                    got,
                    fresh.objective()
                );
                let naive = am.objective(&tenants, &moved);
                assert!(agree(got, naive), "{mode:?}: {} vs naive {}", got, naive);
            }
        }
    }

    #[test]
    fn commit_then_objective_is_drift_free() {
        let (am, tenants) = setup(AlphaMode::Conservative);
        let tables = PrefixTables::for_tenants(&am.cost, &tenants);
        let mut cfg = Config::all_cpu(3);
        cfg.cores = vec![2, 1, 1];
        let mut ev = DeltaEvaluator::new(&am, &tenants, &tables, &cfg);
        for (m, p, cores) in [
            (0usize, 2usize, vec![1usize, 2, 1]),
            (1, 3, vec![1, 1, 2]),
            (2, 6, vec![2, 2, 0]),
            (0, 6, vec![0, 2, 0]),
        ] {
            ev.commit(m, p, &cores);
            cfg.partitions[m] = p;
            cfg.cores = cores;
            // After a commit the cached state is literally a fresh build.
            let fresh = DeltaEvaluator::new(&am, &tenants, &tables, &cfg);
            assert_eq!(ev.objective().to_bits(), fresh.objective().to_bits());
        }
    }

    #[test]
    fn starvation_matches_direct_count() {
        let (am, tenants) = setup(AlphaMode::Conservative);
        let tables = PrefixTables::for_tenants(&am.cost, &tenants);
        let cfg = Config {
            partitions: vec![2, 0, 6],
            cores: vec![0, 0, 0],
        };
        let ev = DeltaEvaluator::new(&am, &tenants, &tables, &cfg);
        // model 0: 4 starved suffix layers; model 1: 6; model 2: full-TPU.
        assert_eq!(ev.score().0, 10);
        let (st, _) = ev.score_move(1, 3, &[0, 0, 0]);
        assert_eq!(st, 7);
        let (st, _) = ev.score_move(1, 3, &[0, 1, 0]);
        assert_eq!(st, 4);
    }

    #[test]
    fn infinite_regimes_detected() {
        let (am, tenants) = setup(AlphaMode::Conservative);
        let tables = PrefixTables::for_tenants(&am.cost, &tenants);
        // Suffix with no core anywhere ⇒ ∞.
        let cfg = Config {
            partitions: vec![3, 6, 6],
            cores: vec![0, 0, 0],
        };
        let ev = DeltaEvaluator::new(&am, &tenants, &tables, &cfg);
        assert!(ev.objective().is_infinite());
        // Moving the starved model to full TPU cures it.
        let (_, obj) = ev.score_move(0, 6, &[0, 0, 0]);
        assert!(obj.is_finite());
    }
}
