//! Event-log parity: the append-only binary log must be a *sufficient
//! statistic* for a run's outcome counters. These tests pin it four ways:
//!
//! 1. across random workloads × disciplines × overload policies, a
//!    rollup replayed from the log reproduces the DES's per-tenant and
//!    per-class counts bit-exactly (property test);
//! 2. a replay from any mid-file record boundary merged onto the prefix
//!    rollup equals the full replay (incremental-view property);
//! 3. a torn tail (a crash mid-append) is detected by length and
//!    skipped, while 40-byte-aligned corruption is a loud error;
//! 4. a logged run round-trips as a trace (format v4): the entry
//!    records reconstruct the arrival stream exactly, and re-simulating
//!    them reproduces the original per-tenant completion counts.

use std::path::PathBuf;

use swapless::analytic::{Config, Tenant};
use swapless::config::HardwareSpec;
use swapless::eventlog::views::Rollup;
use swapless::eventlog::{read_all, read_from, Event, EventKind, EventLog, RECORD_BYTES};
use swapless::model::synthetic_model;
use swapless::sched::{DisciplineKind, OverloadPolicy, SloClass};
use swapless::sim::{SimOptions, Simulator};
use swapless::tpu::CostModel;
use swapless::util::rng::Rng;
use swapless::workload::{generate_arrivals_annotated, trace, RateSchedule};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("swapless-{name}-{}.log", std::process::id()))
}

fn random_tenants(rng: &mut Rng) -> Vec<Tenant> {
    let n = 2 + rng.below(3);
    (0..n)
        .map(|i| {
            let segs = 2 + rng.below(8);
            let mb_total = rng.range_f64(1.0, 30.0);
            let gflops = rng.range_f64(0.2, 8.0);
            Tenant {
                model: synthetic_model(
                    &format!("m{i}"),
                    segs,
                    (mb_total * 1e6 / segs as f64) as u64,
                    (gflops * 1e9 / segs as f64) as u64,
                ),
                rate: rng.range_f64(0.5, 5.0),
            }
        })
        .collect()
}

/// Build a random annotated workload and run it through a logged DES.
/// Returns the sim result and the closed log's events.
fn logged_run(
    seed: u64,
    discipline: DisciplineKind,
    policy: OverloadPolicy,
    warmup: f64,
    device: usize,
    path: &std::path::Path,
) -> (swapless::sim::SimResult, Vec<Event>) {
    const ARRIVAL_SPAN: f64 = 20.0;
    let cost = CostModel::new(HardwareSpec::default());
    let mut rng = Rng::new(seed);
    let tenants = random_tenants(&mut rng);
    let n = tenants.len();
    // Constraint-consistent split: a CPU suffix needs cores, full-TPU
    // holds none (analytic::check_constraints (8)).
    let partitions: Vec<usize> = tenants
        .iter()
        .map(|t| rng.below(t.model.partition_points + 1))
        .collect();
    let cores: Vec<usize> = partitions
        .iter()
        .zip(&tenants)
        .map(|(&p, t)| {
            if p == t.model.partition_points {
                0
            } else {
                1 + rng.below(2)
            }
        })
        .collect();
    let cfg = Config { partitions, cores };
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let classes: Vec<SloClass> = (0..n)
        .map(|_| SloClass::from_index(rng.below(3)).unwrap())
        .collect();
    let deadlines: Vec<Option<f64>> = (0..n)
        .map(|_| {
            if rng.f64() < 0.5 {
                Some(rng.range_f64(0.005, 0.5))
            } else {
                None
            }
        })
        .collect();
    let mut arr_rng = Rng::new(seed ^ 0xABCD);
    let arrivals =
        generate_arrivals_annotated(&schedules, &classes, &deadlines, ARRIVAL_SPAN, &mut arr_rng);

    let log = EventLog::create(path).unwrap();
    let mut sim = Simulator::new(
        &cost,
        &tenants,
        cfg,
        SimOptions {
            horizon: 5000.0,
            warmup,
            seed,
            discipline,
            capacity: Some(1 + rng.below(8)),
            overload: policy,
            device,
            log: Some(log.clone()),
            ..SimOptions::default()
        },
    );
    let res = sim.run(&arrivals, None);
    log.close();
    assert_eq!(log.dropped(), 0, "seed {seed}: bounded channel overflowed");
    let events = read_all(path).unwrap();
    assert_eq!(events.len() as u64, log.appended(), "seed {seed}");
    (res, events)
}

/// Property: the log-derived rollup reproduces the DES's per-tenant and
/// per-class outcome counters bit-exactly, for every discipline and
/// overload policy, with and without a warmup filter.
#[test]
fn prop_log_rollup_matches_sim_counts() {
    let path = tmp("parity");
    for (case, policy) in
        (0..6u64).flat_map(|c| OverloadPolicy::ALL.into_iter().map(move |p| (c, p)))
    {
        let seed = 9000 + case;
        let discipline = DisciplineKind::ALL[case as usize % DisciplineKind::ALL.len()];
        let warmup = if case % 3 == 0 { 5.0 } else { 0.0 };
        let device = (case % 3) as usize;
        let (res, events) = logged_run(seed, discipline, policy, warmup, device, &path);
        let r = Rollup::replay(&events);
        let tag = format!("seed {seed} {discipline} {policy}");

        for (m, stats) in res.per_model.iter().enumerate() {
            let key = (device as u16, m as u64);
            let c = r.per_tenant.get(&key).copied().unwrap_or_default();
            assert_eq!(stats.accepted, c.accepted, "{tag} model {m} accepted");
            assert_eq!(stats.rejected, c.rejected, "{tag} model {m} rejected");
            assert_eq!(stats.shed, c.shed, "{tag} model {m} shed");
            assert_eq!(stats.expired, c.expired, "{tag} model {m} expired");
            assert_eq!(stats.completed, c.completed, "{tag} model {m} completed");
            assert_eq!(stats.latency.count(), c.completed, "{tag} model {m} histogram");
        }
        for class in SloClass::ALL {
            let (live, log) = (&res.per_class, &r.per_class);
            assert_eq!(live.accepted(class), log.accepted(class), "{tag} {class} accepted");
            assert_eq!(live.rejected(class), log.rejected(class), "{tag} {class} rejected");
            assert_eq!(live.shed(class), log.shed(class), "{tag} {class} shed");
            assert_eq!(live.expired(class), log.expired(class), "{tag} {class} expired");
            assert_eq!(live.missed(class), log.missed(class), "{tag} {class} missed");
            assert_eq!(live.get(class).count(), log.get(class).count(), "{tag} {class} hist");
            assert_eq!(live.goodput(class), log.goodput(class), "{tag} {class} goodput");
        }
        // Every record lands on the device this sim instance models.
        assert!(
            r.per_device
                .iter()
                .enumerate()
                .all(|(d, c)| d == device || *c == Default::default()),
            "{tag}: records leaked onto a foreign device"
        );
    }
    let _ = std::fs::remove_file(&path);
}

/// A replay from any mid-file record boundary, merged onto the prefix
/// rollup, equals the full replay — the incremental-view property that
/// lets an auditor resume from a checkpoint offset.
#[test]
fn mid_file_offset_replay_equals_full_minus_prefix() {
    let path = tmp("offsets");
    let (_, events) = logged_run(71, DisciplineKind::Fifo, OverloadPolicy::Reject, 0.0, 0, &path);
    assert!(events.len() > 16, "workload too small to slice");
    let full = Rollup::replay(&events);
    for k in [0, 1, events.len() / 2, events.len() - 1, events.len()] {
        let suffix = read_from(&path, (k * RECORD_BYTES) as u64).unwrap();
        assert_eq!(suffix.len(), events.len() - k, "offset {k}");
        let mut merged = Rollup::replay(&events[..k]);
        merged.merge(&Rollup::replay(&suffix));
        assert_eq!(merged.per_tenant, full.per_tenant, "offset {k} per-tenant");
        assert_eq!(merged.per_device, full.per_device, "offset {k} per-device");
        assert_eq!(merged.records, full.records, "offset {k} records");
        for class in SloClass::ALL {
            assert_eq!(
                merged.per_class.accepted(class),
                full.per_class.accepted(class),
                "offset {k} {class} accepted"
            );
            assert_eq!(
                merged.per_class.get(class).count(),
                full.per_class.get(class).count(),
                "offset {k} {class} histogram"
            );
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// A torn tail — a crash mid-append leaves a partial trailing record —
/// is detected by length and skipped. Aligned garbage is NOT a torn
/// tail and must be a loud error, not a silent skip.
#[test]
fn torn_tail_is_detected_and_skipped() {
    use std::io::Write;
    let path = tmp("torn");
    let log = EventLog::create(&path).unwrap();
    for i in 0..10u64 {
        let mut ev = Event::new(EventKind::Admit, 0.1 * i as f64, 0, i % 3, SloClass::Standard);
        ev.entry = true;
        log.emit(ev);
    }
    log.close();
    assert_eq!(log.appended(), 10);

    // Tear the tail: a partial record (crash mid-write).
    let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
    f.write_all(&[0xAB; RECORD_BYTES - 1]).unwrap();
    drop(f);
    let events = read_all(&path).unwrap();
    assert_eq!(events.len(), 10, "torn tail not skipped");
    assert_eq!(events[3].tenant, 3 % 3);

    // A full-length corrupt record is mid-file corruption, not a tear.
    let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
    f.write_all(&[0xAB; RECORD_BYTES + 1]).unwrap();
    drop(f);
    assert!(
        read_all(&path).is_err(),
        "aligned corruption must not be silently skipped"
    );
    let _ = std::fs::remove_file(&path);
}

/// Trace format v4: a logged run's entry records reconstruct the
/// arrival stream exactly — timestamps, tenants, classes, and absolute
/// deadlines — and re-simulating the loaded trace reproduces the
/// original per-tenant completion counts.
#[test]
fn logged_sim_run_round_trips_as_trace_v4() {
    const ARRIVAL_SPAN: f64 = 20.0;
    let path = tmp("roundtrip");
    let cost = CostModel::new(HardwareSpec::default());
    let tenants = vec![
        Tenant {
            model: synthetic_model("a", 4, 800_000, 300_000_000),
            rate: 3.0,
        },
        Tenant {
            model: synthetic_model("b", 5, 900_000, 350_000_000),
            rate: 2.0,
        },
    ];
    let cfg = Config::all_tpu(&tenants);
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let classes = vec![SloClass::Interactive, SloClass::Batch];
    let deadlines = vec![Some(0.25), None];
    let mut rng = Rng::new(4242);
    let arrivals =
        generate_arrivals_annotated(&schedules, &classes, &deadlines, ARRIVAL_SPAN, &mut rng);
    let opts = SimOptions {
        horizon: 5000.0,
        warmup: 0.0,
        seed: 4242,
        discipline: DisciplineKind::Fifo,
        capacity: Some(4),
        overload: OverloadPolicy::Reject,
        ..SimOptions::default()
    };

    let log = EventLog::create(&path).unwrap();
    let mut sim = Simulator::new(
        &cost,
        &tenants,
        cfg.clone(),
        SimOptions {
            log: Some(log.clone()),
            ..opts.clone()
        },
    );
    let first = sim.run(&arrivals, None);
    log.close();
    assert_eq!(log.dropped(), 0);

    // The binary log sniffs as a log, a JSON trace does not (covered in
    // the unit tests); entry records reconstruct the arrivals exactly.
    let p = path.to_str().unwrap();
    assert!(trace::is_event_log(p));
    let (loaded, n_models) = trace::load_log(p).unwrap();
    assert_eq!(n_models, tenants.len());
    let msg = "entry records must reconstruct the arrival stream bit-exactly";
    assert_eq!(loaded, arrivals, "{msg}");

    // Replaying the loaded trace pins the original per-tenant outcome.
    let mut resim = Simulator::new(&cost, &tenants, cfg, opts);
    let second = resim.run(&loaded, None);
    for (m, (a, b)) in first.per_model.iter().zip(&second.per_model).enumerate() {
        assert_eq!(a.completed, b.completed, "model {m} completed");
        assert_eq!(a.accepted, b.accepted, "model {m} accepted");
        assert_eq!(a.rejected, b.rejected, "model {m} rejected");
    }
    let _ = std::fs::remove_file(&path);
}
