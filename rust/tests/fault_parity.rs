//! Sim-vs-live FAULT parity: under the same crash schedule (one device
//! crashes, no recovery) the multi-device DES failover path
//! ([`run_fleet_failover`]) and the live fleet failover path
//! ([`FleetServer::poll_health`] + forced failover) must agree on the
//! per-tenant completed and failed-over counts.
//!
//! Construction: batch 1 is offered and fully completed while every
//! device is up, then the home of tenant 0 crashes, then batch 2 is
//! offered — so on both paths every tenant completes exactly
//! `BATCH1 + BATCH2` requests, and tenants homed on the crashed device
//! fail over exactly `BATCH2` of them. The DES replays the schedule in
//! virtual time (crash at t = 50 s between the batches); the live side
//! runs the same one-crash schedule against its wall clock, with a
//! heartbeat thread driving `poll_health` the way the serve driver does.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use swapless::analytic::Tenant;
use swapless::config::HardwareSpec;
use swapless::coordinator::AttachOptions;
use swapless::fault::FaultPlan;
use swapless::fleet::{place, run_fleet_failover, Fleet, FleetServerBuilder};
use swapless::model::Manifest;
use swapless::runtime::service::ExecBackend;
use swapless::sched::SloClass;
use swapless::sim::SimOptions;
use swapless::workload::Arrival;

const MODELS: [&str; 3] = ["mobilenetv2", "squeezenet", "inceptionv4"];
const RATES: [f64; 3] = [3.0, 2.0, 1.0];
const BATCH1: usize = 12;
const BATCH2: usize = 12;

fn tenants() -> Vec<Tenant> {
    let manifest = Manifest::synthetic();
    MODELS
        .iter()
        .zip(&RATES)
        .map(|(n, r)| Tenant {
            model: manifest.get(n).unwrap().clone(),
            rate: *r,
        })
        .collect()
}

/// Round-robin deterministic arrivals: `per_tenant` requests per tenant
/// starting at `start`, 50 ms apart, time-sorted.
fn batch(start: f64, per_tenant: usize) -> Vec<Arrival> {
    let mut out = Vec::new();
    for i in 0..per_tenant {
        for m in 0..MODELS.len() {
            out.push(Arrival {
                time: start + 0.05 * (MODELS.len() * i + m) as f64,
                model: m,
                class: SloClass::Standard,
                deadline: None,
            });
        }
    }
    out
}

#[test]
fn fault_sim_vs_live_failover_count_parity() {
    let ts = tenants();
    let fleet = Fleet::uniform(2, &HardwareSpec::default());
    let plan = place(&fleet, &ts);
    assert!(plan.devices.iter().all(|d| !d.tenants.is_empty()));
    let dead = plan.assignment[0];
    let survivor = 1 - dead;

    // --- DES side: crash between the batches in virtual time ---------
    let mut arrivals = batch(0.0, BATCH1);
    arrivals.extend(batch(60.0, BATCH2));
    let mut opts = SimOptions {
        horizon: 1000.0,
        warmup: 0.0,
        seed: 1,
        ..SimOptions::default()
    };
    opts.faults = Some(FaultPlan::new(7).crash(dead, 50.0, None));
    let res = run_fleet_failover(&fleet, &ts, &plan, &arrivals, &opts);
    assert_eq!(res.shed, 0);
    for i in 0..MODELS.len() {
        assert_eq!(
            res.tenant_completed(i),
            (BATCH1 + BATCH2) as u64,
            "DES lost requests of tenant {i}"
        );
        let expect_fo = if plan.assignment[i] == dead {
            BATCH2 as u64
        } else {
            0
        };
        assert_eq!(
            res.tenant_failed_over(i),
            expect_fo,
            "DES failed-over count of tenant {i}"
        );
    }

    // --- live side: same schedule against the wall clock -------------
    let fs = FleetServerBuilder::new(&Manifest::synthetic(), Fleet::uniform(2, &HardwareSpec::default()))
        .backend(ExecBackend::Emulated)
        .adaptive(false)
        .faults(FaultPlan::new(7).crash(dead, 1.5, None))
        .build()
        .unwrap();
    let fs = Arc::new(fs);
    // Heartbeat: the same caller-driven health poll the serve driver
    // runs — makes the test immune to the crash racing batch 1 (queued
    // work on the crashed device is requeued, never stranded).
    let stop = Arc::new(AtomicBool::new(false));
    let poller = {
        let fs = Arc::clone(&fs);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                fs.poll_health();
                std::thread::sleep(Duration::from_millis(10));
            }
        })
    };

    let mut handle_of = vec![None; MODELS.len()];
    for dp in &plan.devices {
        for &g in &dp.tenants {
            let h = fs
                .attach_on(
                    MODELS[g],
                    AttachOptions {
                        rate_hint: RATES[g],
                        class: SloClass::Standard,
                    },
                    dp.device,
                )
                .unwrap();
            handle_of[g] = Some(h);
        }
        fs.set_device_config(dp.device, dp.config.clone()).unwrap();
    }
    let inputs: Vec<usize> = ts
        .iter()
        .map(|t| t.model.input_shape.iter().product())
        .collect();

    // Batch 1: everything up (emulated at time_scale 0 completes in
    // milliseconds, far inside the 1.5 s pre-crash window).
    let mut live_completed = vec![0u64; MODELS.len()];
    let mut pending = Vec::new();
    for _ in 0..BATCH1 {
        for (m, h) in handle_of.iter().enumerate() {
            pending.push((m, fs.submit(h.unwrap(), vec![0.5f32; inputs[m]])));
        }
    }
    for (m, ticket) in pending {
        ticket.wait().unwrap_or_else(|e| panic!("batch1 tenant {m}: {e}"));
        live_completed[m] += 1;
    }

    // Wait for the injected crash and the heartbeat's forced failover:
    // every tenant homed on the dead device lands on the survivor.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let all_moved = (0..MODELS.len()).all(|i| {
            plan.assignment[i] != dead
                || fs.device_of(handle_of[i].unwrap()) == Some(survivor)
        });
        if fs.health()[dead].is_down() && all_moved {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "failover never observed: health={:?}",
            fs.health()
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // Batch 2: offered against the degraded fleet.
    let mut pending = Vec::new();
    for _ in 0..BATCH2 {
        for (m, h) in handle_of.iter().enumerate() {
            pending.push((m, fs.submit(h.unwrap(), vec![0.5f32; inputs[m]])));
        }
    }
    for (m, ticket) in pending {
        ticket.wait().unwrap_or_else(|e| panic!("batch2 tenant {m}: {e}"));
        live_completed[m] += 1;
    }
    stop.store(true, Ordering::Relaxed);
    poller.join().unwrap();

    // --- parity -------------------------------------------------------
    let stats = fs.stats();
    assert_eq!(stats.failovers, 1, "exactly one forced failover");
    assert_eq!(stats.shed_tenants, 0);
    for i in 0..MODELS.len() {
        assert_eq!(
            live_completed[i],
            res.tenant_completed(i),
            "completed parity broke for tenant {i}"
        );
        assert_eq!(
            fs.failed_over_of(handle_of[i].unwrap()),
            res.tenant_failed_over(i),
            "failed-over parity broke for tenant {i}"
        );
    }
    let live_total: u64 = live_completed.iter().sum();
    assert_eq!(live_total, res.completed);
}
