//! Socket-path parity: a request submitted over the wire protocol must
//! land in exactly the same admission/accounting machinery as one
//! submitted in-process. Two identically-configured servers driven with
//! the same deterministic request sequence — one through
//! `Server::submit`, one through a `NetListener` TCP connection — must
//! end with identical per-tenant accepted/completed counts and
//! per-class counters, on a single device and through the fleet router.
//!
//! Alongside parity: typed-error handling for malformed/truncated/
//! oversized frames (no panic, no hang, server survives), graceful
//! drain-on-shutdown under live socket load, and the HTTP stats
//! endpoint.

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use swapless::analytic::TenantHandle;
use swapless::config::HardwareSpec;
use swapless::coordinator::{AttachOptions, Request, Server, ServerBuilder};
use swapless::eventlog::EventLog;
use swapless::fleet::{Fleet, FleetServer, FleetServerBuilder};
use swapless::model::Manifest;
use swapless::net::loadgen::{self, LoadgenMode, LoadgenOptions, TenantSpec};
use swapless::net::proto::{
    encode_payload, write_frame, ErrorCode, FrameHeader, FrameKind, FrameReader, HEADER_BYTES,
    MAGIC, VERSION, WireError,
};
use swapless::net::{NetListener, NetOptions, WireBackend};
use swapless::runtime::service::ExecBackend;
use swapless::sched::{OverloadPolicy, SloClass};
use swapless::tpu::CostModel;
use swapless::util::rng::Rng;
use swapless::workload::RateSchedule;

/// Models with comfortably multi-ms service estimates: a 1 ms relative
/// deadline is below either hint, so `DeadlineDrop` rejects it at entry
/// deterministically (no timing involved).
const MODELS: [&str; 2] = ["mobilenetv2", "inceptionv4"];
const STEPS: usize = 60;

/// One deterministic request: tenant round-robin, class cycling through
/// {default, Interactive, Batch}, every 5th carrying the 1 ms deadline
/// that must expire at admission.
struct Step {
    tenant: usize,
    class: Option<SloClass>,
    deadline_ms: u32,
}

fn steps() -> Vec<Step> {
    (0..STEPS)
        .map(|i| Step {
            tenant: i % MODELS.len(),
            class: match i % 3 {
                0 => None,
                1 => Some(SloClass::Interactive),
                _ => Some(SloClass::Batch),
            },
            deadline_ms: if i % 5 == 4 { 1 } else { 0 },
        })
        .collect()
}

fn expired_steps() -> u64 {
    steps().iter().filter(|s| s.deadline_ms > 0).count() as u64
}

fn build_server(log: Option<EventLog>) -> Arc<Server> {
    let manifest = Manifest::synthetic();
    let mut b = ServerBuilder::new(&manifest, CostModel::new(HardwareSpec::default()))
        .backend(ExecBackend::Emulated)
        .adaptive(false)
        .overload(OverloadPolicy::DeadlineDrop);
    if let Some(l) = log {
        b = b.log(l);
    }
    Arc::new(b.build().expect("build server"))
}

fn attach_all(server: &Server) -> Vec<(TenantHandle, usize)> {
    let manifest = Manifest::synthetic();
    MODELS
        .iter()
        .map(|name| {
            let h = server
                .attach(
                    name,
                    AttachOptions {
                        rate_hint: 4.0,
                        class: SloClass::Standard,
                    },
                )
                .expect("attach");
            let n: usize = manifest.get(name).unwrap().input_shape.iter().product();
            (h, n)
        })
        .collect()
}

/// Read the next frame, polling through read timeouts, with a hard
/// bound so a protocol bug fails the test instead of hanging it.
fn read_frame(reader: &mut FrameReader, stream: &mut TcpStream) -> Option<(FrameHeader, Vec<u8>)> {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match reader.next_frame(stream) {
            Ok(Some((h, payload))) => return Some((h, payload.to_vec())),
            Ok(None) => return None,
            Err(WireError::Io(_)) => {
                assert!(Instant::now() < deadline, "timed out waiting for a frame");
            }
            Err(e) => panic!("client-side parse error: {e}"),
        }
    }
}

/// Drive the deterministic sequence over an established wire connection
/// (closed loop: next request only after this one's frame came back).
fn drive_wire(addr: &str, tenants: &[(TenantHandle, usize)]) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_millis(20)))
        .unwrap();
    let mut reader = FrameReader::new();
    let mut payload = Vec::new();

    // Typed handshake: every handle answers Query with its input length.
    for (i, (h, n_in)) in tenants.iter().enumerate() {
        write_frame(&mut stream, &FrameHeader::query(h.0, i as u64), &[]).unwrap();
        let (info, _) = read_frame(&mut reader, &mut stream).expect("info frame");
        assert_eq!(info.kind, FrameKind::Info);
        assert_eq!(info.seq, i as u64);
        assert_eq!(info.arg as usize, *n_in);
    }
    // An unknown handle gets a typed NotAttached, not a hang or close.
    write_frame(&mut stream, &FrameHeader::query(9999, 77), &[]).unwrap();
    let (refused, _) = read_frame(&mut reader, &mut stream).expect("error frame");
    assert_eq!(refused.kind, FrameKind::Error);
    assert_eq!(refused.code, ErrorCode::NotAttached as u8);

    for (i, s) in steps().iter().enumerate() {
        let (h, n_in) = tenants[s.tenant];
        encode_payload(&vec![0.5f32; n_in], &mut payload);
        let header =
            FrameHeader::submit(h.0, i as u64, s.class, s.deadline_ms, payload.len() as u32);
        write_frame(&mut stream, &header, &payload).unwrap();
        let (resp, body) = read_frame(&mut reader, &mut stream).expect("response frame");
        assert_eq!(resp.seq, i as u64, "responses come back in closed loop");
        assert_eq!(resp.tenant, h.0);
        if s.deadline_ms > 0 {
            assert_eq!(resp.kind, FrameKind::Error, "1 ms deadline must expire");
            assert_eq!(resp.code, ErrorCode::Expired as u8);
        } else {
            assert_eq!(resp.kind, FrameKind::Response);
            assert!(!body.is_empty(), "completion carries the output tensor");
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// Drive the identical sequence through `submit` directly.
fn drive_direct<F>(tenants: &[(TenantHandle, usize)], submit: F)
where
    F: Fn(TenantHandle, Request) -> swapless::coordinator::Ticket,
{
    for s in steps() {
        let (h, n_in) = tenants[s.tenant];
        let mut req = Request::new(vec![0.5f32; n_in]);
        if let Some(c) = s.class {
            req = req.with_class(c);
        }
        if s.deadline_ms > 0 {
            req = req.with_deadline(Duration::from_millis(u64::from(s.deadline_ms)));
        }
        let outcome = submit(h, req).wait();
        if s.deadline_ms > 0 {
            assert!(outcome.is_err(), "1 ms deadline must expire at admission");
        } else {
            outcome.expect("deadline-free request completes");
        }
    }
}

/// The parity claim: identical counts, not just similar ones.
fn assert_stats_parity(
    direct: &swapless::coordinator::ServeStats,
    wire: &swapless::coordinator::ServeStats,
    label: &str,
) {
    assert_eq!(direct.accepted, wire.accepted, "{label}: accepted");
    assert_eq!(direct.completed, wire.completed, "{label}: completed");
    assert_eq!(direct.rejected, wire.rejected, "{label}: rejected");
    assert_eq!(direct.shed, wire.shed, "{label}: shed");
    assert_eq!(direct.expired, wire.expired, "{label}: expired");
    assert_eq!(direct.cancelled, wire.cancelled, "{label}: cancelled");
    assert_eq!(direct.failed, wire.failed, "{label}: failed");
    assert_eq!(
        direct.per_tenant.len(),
        wire.per_tenant.len(),
        "{label}: tenant rows"
    );
    for (d, w) in direct.per_tenant.iter().zip(&wire.per_tenant) {
        assert_eq!(d.name, w.name, "{label}: tenant order");
        assert_eq!(d.handle, w.handle, "{label}: {} handle", d.name);
        assert_eq!(d.accepted, w.accepted, "{label}: {} accepted", d.name);
        assert_eq!(
            d.latency.count(),
            w.latency.count(),
            "{label}: {} completed",
            d.name
        );
    }
    for c in SloClass::ALL {
        assert_eq!(
            direct.per_class.get(c).count(),
            wire.per_class.get(c).count(),
            "{label}: class {c} completions"
        );
        assert_eq!(
            direct.per_class.accepted(c),
            wire.per_class.accepted(c),
            "{label}: class {c} accepted"
        );
        assert_eq!(
            direct.per_class.dropped(c),
            wire.per_class.dropped(c),
            "{label}: class {c} dropped"
        );
        assert_eq!(
            direct.per_class.goodput(c),
            wire.per_class.goodput(c),
            "{label}: class {c} goodput"
        );
    }
}

#[test]
fn single_device_socket_matches_direct_submission() {
    // Direct path.
    let direct = build_server(None);
    let direct_tenants = attach_all(&direct);
    drive_direct(&direct_tenants, |h, req| direct.submit(h, req));

    // Wire path — same server build, plus the event log satellite: wire
    // admits/rejects/completes flow into the append-only log because
    // they share the submit path.
    let log_path = std::env::temp_dir().join(format!("net_parity_{}.evlog", std::process::id()));
    let log = EventLog::create(log_path.to_str().unwrap()).expect("event log");
    let wire = build_server(Some(log.clone()));
    let wire_tenants = attach_all(&wire);
    let listener =
        NetListener::bind(wire.clone(), "127.0.0.1:0", NetOptions::default()).expect("bind");
    drive_wire(&listener.local_addr().to_string(), &wire_tenants);

    let net = listener.shutdown();
    assert_eq!(net.accepted_conns, 1);
    assert_eq!(net.malformed, 0);
    assert_eq!(net.frames_in, STEPS as u64);
    // responses_* count Submit tickets only (the NotAttached probe reply
    // rides the Info path).
    assert_eq!(net.responses_err, expired_steps());
    assert_eq!(
        net.frames_in,
        net.responses_ok + net.responses_err,
        "every parsed submit got exactly one response"
    );

    assert_stats_parity(&direct.stats(), &wire.stats(), "single-device");
    let expected = expired_steps();
    assert_eq!(wire.stats().expired, expected);
    assert_eq!(wire.stats().completed, STEPS as u64 - expected);

    // Closing the server finalizes the log; the wire traffic is in it.
    drop(wire);
    assert!(log.appended() > 0, "wire requests reached the event log");
    assert_eq!(log.dropped(), 0);
    let _ = std::fs::remove_file(&log_path);
}

fn build_fleet() -> Arc<FleetServer> {
    let fleet = Fleet::uniform(2, &HardwareSpec::default());
    Arc::new(
        FleetServerBuilder::new(&Manifest::synthetic(), fleet)
            .backend(ExecBackend::Emulated)
            .adaptive(false)
            .overload(OverloadPolicy::DeadlineDrop)
            .build()
            .expect("build fleet"),
    )
}

/// Pin each tenant to its own device so both fleet instances share a
/// placement and per-device counters are comparable.
fn attach_fleet(fs: &FleetServer) -> Vec<(TenantHandle, usize)> {
    let manifest = Manifest::synthetic();
    MODELS
        .iter()
        .enumerate()
        .map(|(device, name)| {
            let h = fs
                .attach_on(
                    name,
                    AttachOptions {
                        rate_hint: 4.0,
                        class: SloClass::Standard,
                    },
                    device,
                )
                .expect("attach_on");
            assert_eq!(fs.device_of(h), Some(device));
            let n: usize = manifest.get(name).unwrap().input_shape.iter().product();
            (h, n)
        })
        .collect()
}

#[test]
fn fleet_socket_matches_direct_submission() {
    let direct = build_fleet();
    let direct_tenants = attach_fleet(&direct);
    drive_direct(&direct_tenants, |h, req| direct.submit(h, req));

    let wire = build_fleet();
    let wire_tenants = attach_fleet(&wire);
    // The wire handshake resolves input lengths through the fleet's own
    // attachment table.
    for (h, n_in) in &wire_tenants {
        assert_eq!(WireBackend::input_len(wire.as_ref(), *h), Some(*n_in));
    }
    let listener =
        NetListener::bind(wire.clone(), "127.0.0.1:0", NetOptions::default()).expect("bind");
    drive_wire(&listener.local_addr().to_string(), &wire_tenants);
    let net = listener.shutdown();
    assert_eq!(net.malformed, 0);
    assert_eq!(net.frames_in, STEPS as u64);

    let (ds, ws) = (direct.stats(), wire.stats());
    assert_eq!(ds.per_device.len(), ws.per_device.len());
    for (d, (dd, wd)) in ds.per_device.iter().zip(&ws.per_device).enumerate() {
        assert_stats_parity(dd, wd, &format!("fleet device {d}"));
        // Both devices saw traffic — the placement pinned one tenant on
        // each, and the router kept it there.
        assert!(wd.completed > 0, "device {d} idle on the wire path");
    }
    assert_eq!(ds.completed(), ws.completed());
    for c in SloClass::ALL {
        assert_eq!(
            ds.per_class().get(c).count(),
            ws.per_class().get(c).count(),
            "fleet class {c}"
        );
    }
}

/// Build a raw frame-shaped byte buffer with targeted corruption.
fn raw_header(mutate: impl Fn(&mut [u8; HEADER_BYTES])) -> Vec<u8> {
    let mut buf = [0u8; HEADER_BYTES];
    FrameHeader::submit(0, 1, None, 0, 0).encode(&mut buf);
    mutate(&mut buf);
    buf.to_vec()
}

/// Write `bytes`, half-close, and collect the typed reply: `Some(code)`
/// when an Error frame came back, `None` on a bare close. Bounded.
fn poke(addr: &str, bytes: &[u8]) -> Option<u8> {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_millis(20)))
        .unwrap();
    stream.write_all(bytes).expect("write");
    let _ = stream.shutdown(Shutdown::Write);
    let mut reader = FrameReader::new();
    let frame = read_frame(&mut reader, &mut stream);
    frame.map(|(h, _)| {
        assert_eq!(h.kind, FrameKind::Error, "server replies are typed errors");
        h.code
    })
}

#[test]
fn malformed_frames_get_typed_errors_and_never_kill_the_server() {
    let server = build_server(None);
    let tenants = attach_all(&server);
    let listener =
        NetListener::bind(server.clone(), "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = listener.local_addr().to_string();

    let cases: Vec<(&str, Vec<u8>)> = vec![
        ("bad magic", raw_header(|b| b[0] = 0x00)),
        ("bad version", raw_header(|b| b[2] = VERSION + 9)),
        ("unknown kind", raw_header(|b| b[3] = 0x2A)),
        ("unknown class", raw_header(|b| b[4] = 7)),
        ("nonzero flags", raw_header(|b| b[6] = 1)),
        (
            "oversized payload",
            raw_header(|b| b[28..32].copy_from_slice(&(64u32 << 20).to_le_bytes())),
        ),
        (
            "misaligned payload",
            raw_header(|b| b[28..32].copy_from_slice(&6u32.to_le_bytes())),
        ),
        (
            "stray payload on query",
            raw_header(|b| {
                b[3] = FrameKind::Query as u8;
                b[28..32].copy_from_slice(&8u32.to_le_bytes());
            }),
        ),
        (
            "server-side kind from client",
            raw_header(|b| b[3] = FrameKind::Response as u8),
        ),
        (
            "truncated mid-payload",
            {
                let mut bytes = raw_header(|b| b[28..32].copy_from_slice(&2048u32.to_le_bytes()));
                bytes.extend_from_slice(&[0u8; 100]); // 100 of 2048 payload bytes
                bytes
            },
        ),
        ("truncated mid-header", vec![MAGIC[0], MAGIC[1], VERSION]),
    ];
    for (label, bytes) in cases {
        assert_eq!(
            poke(&addr, &bytes),
            Some(ErrorCode::Malformed as u8),
            "case {label:?}"
        );
    }

    // Seeded arbitrary bytes: typed error or clean close, never a hang.
    let mut rng = Rng::new(0xF00D);
    for _ in 0..16 {
        let n = 1 + rng.below(128);
        let blob: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let _ = poke(&addr, &blob); // read_frame bounds the wait; poke types any reply
    }

    // A frame-shaped lie: well-formed Submit header whose payload is a
    // length the model rejects — typed Execution error, no panic.
    let empty_input = raw_header(|_| {});
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_millis(20)))
        .unwrap();
    stream.write_all(&empty_input).unwrap();
    let mut reader = FrameReader::new();
    let (h, _) = read_frame(&mut reader, &mut stream).expect("typed reply");
    assert_eq!(h.kind, FrameKind::Error);
    assert_eq!(h.code, ErrorCode::Execution as u8);
    drop(stream);

    // The server survived all of it: a well-formed request still works.
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_millis(20)))
        .unwrap();
    let (th, n_in) = tenants[0];
    let mut payload = Vec::new();
    encode_payload(&vec![0.5f32; n_in], &mut payload);
    write_frame(
        &mut stream,
        &FrameHeader::submit(th.0, 1, None, 0, payload.len() as u32),
        &payload,
    )
    .unwrap();
    let mut reader = FrameReader::new();
    let (ok, body) = read_frame(&mut reader, &mut stream).expect("response");
    assert_eq!(ok.kind, FrameKind::Response);
    assert!(!body.is_empty());
    drop(stream);

    let net = listener.shutdown();
    assert!(net.malformed >= 10, "every corrupt case counted");
    assert_eq!(
        net.frames_in,
        net.responses_ok + net.responses_err,
        "accounting stays exact under hostile input"
    );
}

#[test]
fn shutdown_mid_load_resolves_every_accepted_request() {
    let server = build_server(None);
    let tenants = attach_all(&server);
    let listener =
        NetListener::bind(server.clone(), "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = listener.local_addr().to_string();

    // Closed-loop load from two connections, nominally for 60 s — the
    // shutdown below cuts it off after ~0.4 s.
    let opts = LoadgenOptions {
        addr,
        connections: 2,
        duration_s: 60.0,
        mode: LoadgenMode::Closed,
        tenants: tenants
            .iter()
            .map(|(h, _)| TenantSpec {
                handle: h.0,
                schedule: RateSchedule::constant(1.0),
                class: None,
                deadline_ms: 0,
            })
            .collect(),
        window: 4,
        seed: 7,
    };
    let client = std::thread::spawn(move || loadgen::run(&opts).expect("loadgen"));
    std::thread::sleep(Duration::from_millis(400));
    let net = listener.shutdown();
    let report = client.join().expect("client thread");

    // Server side: every frame it parsed was answered — response or
    // typed error, no silent drops.
    assert!(net.frames_in > 0, "load reached the server");
    assert_eq!(net.frames_in, net.responses_ok + net.responses_err);
    // Client side: full accounting. Requests the listener never parsed
    // (in flight in the socket when it stopped reading) are the only
    // unanswered ones — bounded by the in-flight windows.
    assert_eq!(
        report.sent,
        report.completed + report.errors + report.unanswered
    );
    assert!(report.completed > 0);
    assert!(
        report.unanswered <= 2 * 4,
        "unanswered {} exceeds the outstanding windows",
        report.unanswered
    );
    assert_eq!(report.completed + report.errors, net.responses_ok + net.responses_err);
}

#[test]
fn http_stats_endpoint_serves_the_grep_lines() {
    use std::io::Read;
    let server = build_server(None);
    let _tenants = attach_all(&server);
    let listener =
        NetListener::bind(server.clone(), "127.0.0.1:0", NetOptions::default()).expect("bind");
    let mut stream = TcpStream::connect(listener.local_addr()).unwrap();
    stream
        .write_all(b"GET /stats HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut body = String::new();
    stream.read_to_string(&mut body).unwrap();
    assert!(body.starts_with("HTTP/1.1 200 OK"), "{body}");
    assert!(body.contains("overload: accepted="), "{body}");

    let mut stream = TcpStream::connect(listener.local_addr()).unwrap();
    stream
        .write_all(b"GET /nope HTTP/1.1\r\nHost: t\r\n\r\n")
        .unwrap();
    let mut body = String::new();
    stream.read_to_string(&mut body).unwrap();
    assert!(body.starts_with("HTTP/1.1 404"), "{body}");

    let net = listener.shutdown();
    assert_eq!(net.http_requests, 2);
}
