//! Property-based tests: randomized invariants over the allocator, the
//! queueing model, the SRAM cache, and the substrates. The offline build
//! carries no proptest crate, so generation/shrinking-lite is driven by
//! the in-repo deterministic RNG — every case prints its seed on failure.

use swapless::alloc;
use swapless::analytic::{
    check_constraints, objective_with_tables, AlphaMode, AnalyticModel, Config, DeltaEvaluator,
    Tenant,
};
use swapless::config::HardwareSpec;
use swapless::metrics::LatencyHistogram;
use swapless::model::synthetic_model;
use swapless::sim::{simulate, SimOptions};
use swapless::tpu::{CostModel, PrefixTables, SramCache};
use swapless::util::json::{parse, Json};
use swapless::util::rng::Rng;

const CASES: usize = 60;

fn random_tenants(rng: &mut Rng) -> Vec<Tenant> {
    let n = 1 + rng.below(4);
    (0..n)
        .map(|i| {
            let segs = 2 + rng.below(10);
            let mb_total = rng.range_f64(1.0, 45.0);
            let gflops = rng.range_f64(0.2, 12.0);
            Tenant {
                model: synthetic_model(
                    &format!("m{i}"),
                    segs,
                    (mb_total * 1e6 / segs as f64) as u64,
                    (gflops * 1e9 / segs as f64) as u64,
                ),
                rate: rng.range_f64(0.1, 6.0),
            }
        })
        .collect()
}

#[test]
fn prop_hill_climb_always_feasible() {
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let k_max = 1 + rng.below(6);
        let a = alloc::hill_climb(&am, &tenants, k_max);
        check_constraints(&tenants, &a.config, k_max)
            .unwrap_or_else(|e| panic!("seed {seed}: infeasible config: {e}"));
    }
}

#[test]
fn prop_hill_climb_never_worse_than_endpoints() {
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    for seed in 100..100 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let k_max = 2 + rng.below(4);
        let a = alloc::hill_climb(&am, &tenants, k_max);
        let all_cpu = Config {
            partitions: vec![0; tenants.len()],
            cores: alloc::prop_alloc(&am.cost, &tenants, &vec![0; tenants.len()], k_max),
        };
        let all_tpu = Config::all_tpu(&tenants);
        assert!(
            a.predicted_objective <= am.objective(&tenants, &all_cpu) + 1e-9,
            "seed {seed}: worse than all-CPU (the start point)"
        );
        // Alg. 1 is a greedy heuristic with 2-step lookahead — it can stop
        // at a local optimum above the all-TPU endpoint, but never by a
        // large factor on these instances.
        let tpu_obj = am.objective(&tenants, &all_tpu);
        if tpu_obj.is_finite() {
            assert!(
                a.predicted_objective <= tpu_obj * 1.6 + 1e-9,
                "seed {seed}: {:.4} far above all-TPU {:.4}",
                a.predicted_objective,
                tpu_obj
            );
        }
    }
}

#[test]
fn prop_hill_climb_beats_or_matches_baselines() {
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    for seed in 200..200 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let co = alloc::edge_tpu_compiler(&am, &tenants);
        let th = alloc::threshold_partitioning(&am, &tenants, 4, 0.10);
        let hc = alloc::hill_climb(&am, &tenants, 4);
        assert!(
            hc.predicted_objective <= co.predicted_objective + 1e-9,
            "seed {seed}: lost to compiler baseline"
        );
        assert!(
            hc.predicted_objective <= th.predicted_objective + 1e-9,
            "seed {seed}: lost to threshold baseline"
        );
    }
}

#[test]
fn prop_alpha_in_unit_interval_and_regimes() {
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    for seed in 300..300 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let partitions: Vec<usize> = tenants
            .iter()
            .map(|t| rng.below(t.model.partition_points + 1))
            .collect();
        let cores = alloc::prop_alloc(&am.cost, &tenants, &partitions, 4);
        let cfg = Config { partitions, cores };
        let total_resident: u64 = tenants
            .iter()
            .enumerate()
            .map(|(i, t)| am.cost.resident_bytes(&t.model, cfg.partitions[i]))
            .sum();
        let active = tenants
            .iter()
            .enumerate()
            .filter(|(i, t)| cfg.partitions[*i] > 0 && t.rate > 0.0)
            .count();
        let mut alpha_sum = 0.0;
        for i in 0..tenants.len() {
            let a = am.alpha(&tenants, &cfg, i);
            assert!((0.0..=1.0).contains(&a), "seed {seed}: α={a}");
            if total_resident <= am.cost.hw.sram_bytes || active <= 1 {
                assert_eq!(a, 0.0, "seed {seed}: α must be 0 in regime 1");
            }
            if cfg.partitions[i] > 0 {
                alpha_sum += a;
            }
        }
        // Σ(1 - λi/λ) over active models = active - 1 when in regime 2.
        if active > 1 && total_resident > am.cost.hw.sram_bytes {
            assert!(
                (alpha_sum - (active as f64 - 1.0)).abs() < 1e-9,
                "seed {seed}: Σα = {alpha_sum}, active {active}"
            );
        }
    }
}

#[test]
fn prop_prop_alloc_invariants() {
    let cost = CostModel::new(HardwareSpec::default());
    for seed in 400..400 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let k_max = rng.below(9);
        let partitions: Vec<usize> = tenants
            .iter()
            .map(|t| rng.below(t.model.partition_points + 1))
            .collect();
        let cores = alloc::prop_alloc(&cost, &tenants, &partitions, k_max);
        assert!(cores.iter().sum::<usize>() <= k_max, "seed {seed}: over cap");
        for (i, t) in tenants.iter().enumerate() {
            if partitions[i] == t.model.partition_points {
                assert_eq!(cores[i], 0, "seed {seed}: full-TPU model got cores");
            }
        }
        let eligible = partitions
            .iter()
            .zip(&tenants)
            .filter(|(p, t)| **p < t.model.partition_points)
            .count();
        if eligible > 0 && k_max >= eligible {
            // constraint-(8) floor is satisfiable -> every suffix gets ≥1
            for (i, t) in tenants.iter().enumerate() {
                if partitions[i] < t.model.partition_points {
                    assert!(cores[i] >= 1, "seed {seed}: suffix model starved");
                }
            }
        }
    }
}

#[test]
fn prop_latency_monotone_in_rate() {
    // Analytic e2e latency must be nondecreasing in the arrival rate.
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    for seed in 500..500 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let mut tenants = random_tenants(&mut rng);
        tenants.truncate(1);
        let p = 1 + rng.below(tenants[0].model.partition_points);
        let k = if p < tenants[0].model.partition_points { 2 } else { 0 };
        let cfg = Config {
            partitions: vec![p],
            cores: vec![k],
        };
        let mut prev = 0.0;
        for step in 1..10 {
            tenants[0].rate = step as f64 * 0.5;
            let lat = am.e2e_latency(&tenants, &cfg, 0);
            if lat.is_infinite() {
                break;
            }
            assert!(
                lat >= prev - 1e-12,
                "seed {seed}: latency decreased with load"
            );
            prev = lat;
        }
    }
}

#[test]
fn prop_cache_used_never_exceeds_capacity() {
    for seed in 600..600 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let cap = 1_000_000 + rng.below(9_000_000) as u64;
        let mut cache = SramCache::new(cap);
        for _ in 0..300 {
            let id = rng.below(6);
            let bytes = (rng.f64() * cap as f64) as u64;
            cache.access(id, bytes);
            assert!(cache.used_bytes() <= cap, "seed {seed}: over capacity");
        }
    }
}

#[test]
fn prop_cache_all_fit_implies_steady_hits() {
    for seed in 700..700 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let n = 2 + rng.below(4);
        let per = 1_000_000u64;
        let mut cache = SramCache::new(per * n as u64 + 1);
        // warm
        for id in 0..n {
            cache.access(id, per);
        }
        for _ in 0..100 {
            let id = rng.below(n);
            assert!(cache.access(id, per), "seed {seed}: miss though all fit");
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.f64() * 1e6).round() / 4.0),
            3 => {
                let len = rng.below(12);
                Json::Str(
                    (0..len)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(5) {
                    o.set(&format!("k{i}"), random_json(rng, depth - 1));
                }
                o
            }
        }
    }
    for seed in 800..800 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let v = random_json(&mut rng, 3);
        let text = v.to_string_pretty();
        let back = parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

#[test]
fn prop_des_matches_analytic_on_stable_single_tenant() {
    // The DES and the queueing formulas must agree (within Monte-Carlo
    // noise) wherever the analytic assumptions hold exactly.
    let cost = CostModel::new(HardwareSpec::default());
    let am = AnalyticModel::new(cost.clone());
    let mut checked = 0;
    for seed in 900..950u64 {
        let mut rng = Rng::new(seed);
        let mut tenants = random_tenants(&mut rng);
        tenants.truncate(1);
        let pp = tenants[0].model.partition_points;
        let p = rng.below(pp + 1);
        let cores = alloc::prop_alloc(&cost, &tenants, &[p], 4);
        let cfg = Config {
            partitions: vec![p],
            cores,
        };
        let predicted = am.e2e_latency(&tenants, &cfg, 0);
        let rho = am.tpu_utilization(&tenants, &cfg);
        if !predicted.is_finite() || rho > 0.7 {
            continue; // skip unstable / heavy-traffic cases (slow mixing)
        }
        let res = simulate(
            &cost,
            &tenants,
            &cfg,
            SimOptions {
                horizon: 1500.0,
                warmup: 75.0,
                seed,
                ..SimOptions::default()
            },
        );
        let err = (res.mean_latency - predicted).abs() / predicted;
        assert!(
            err < 0.08,
            "seed {seed}: DES {} vs analytic {} ({:.1}%)",
            res.mean_latency,
            predicted,
            err * 100.0
        );
        checked += 1;
    }
    assert!(checked >= 10, "too few stable cases checked ({checked})");
}

/// ∞ must match ∞; finite values must agree to 1e-9 relative.
fn agree(a: f64, b: f64) -> bool {
    if a.is_infinite() || b.is_infinite() {
        return a.is_infinite() && b.is_infinite() && a.signum() == b.signum();
    }
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
}

/// A random configuration for `tenants` — arbitrary partitions and cores,
/// deliberately including infeasible ones (suffix with zero cores) so the
/// divergent regimes are exercised too.
fn random_config(rng: &mut Rng, tenants: &[Tenant]) -> Config {
    let partitions: Vec<usize> = tenants
        .iter()
        .map(|t| rng.below(t.model.partition_points + 1))
        .collect();
    let cores: Vec<usize> = (0..tenants.len()).map(|_| rng.below(4)).collect();
    Config { partitions, cores }
}

const MODES: [AlphaMode; 3] = [
    AlphaMode::Conservative,
    AlphaMode::Pairwise,
    AlphaMode::Zero,
];

#[test]
fn prop_prefix_tables_bitexact() {
    // Table entries must equal the naive CostModel answers bit-for-bit —
    // the tables accumulate in the same order as the per-call loops.
    let cost = CostModel::new(HardwareSpec::default());
    for seed in 1100..1100 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let segs = 2 + rng.below(11);
        let mb_total = rng.range_f64(0.5, 50.0);
        let gflops = rng.range_f64(0.1, 15.0);
        let m = synthetic_model(
            "m",
            segs,
            (mb_total * 1e6 / segs as f64) as u64,
            (gflops * 1e9 / segs as f64) as u64,
        );
        let t = PrefixTables::new(&cost, &m);
        for p in 0..=segs {
            assert_eq!(
                t.tpu_service(p).to_bits(),
                cost.tpu_service(&m, p).to_bits(),
                "seed {seed} p={p}: tpu_service"
            );
            assert_eq!(
                t.cpu_service(p).to_bits(),
                cost.cpu_service(&m, p).to_bits(),
                "seed {seed} p={p}: cpu_service"
            );
            assert_eq!(
                t.resident_bytes(p),
                cost.resident_bytes(&m, p),
                "seed {seed} p={p}: resident_bytes"
            );
            assert_eq!(
                t.load_time(p).to_bits(),
                cost.load_time(&m, p).to_bits(),
                "seed {seed} p={p}: load_time"
            );
            assert_eq!(
                t.intra_swap_time(p).to_bits(),
                cost.intra_swap_time(&m, p).to_bits(),
                "seed {seed} p={p}: intra_swap_time"
            );
            assert_eq!(
                t.output_transfer(p).to_bits(),
                cost.output_transfer(&m, p).to_bits(),
                "seed {seed} p={p}: output_transfer"
            );
        }
        assert_eq!(t.input_transfer().to_bits(), cost.input_transfer(&m).to_bits());
    }
}

#[test]
fn prop_delta_evaluator_matches_naive_objective() {
    // ≥1000 randomized (mix, partition, rate, α-mode) configurations:
    // the table-backed evaluator must agree with the naive objective()
    // within 1e-9 relative (∞ matching ∞ exactly).
    let cost = CostModel::new(HardwareSpec::default());
    let mut checked = 0usize;
    for seed in 2000..2000 + 120u64 {
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let tables = PrefixTables::for_tenants(&cost, &tenants);
        for mode in MODES {
            let am = AnalyticModel::with_alpha_mode(cost.clone(), mode);
            for _ in 0..3 {
                let cfg = random_config(&mut rng, &tenants);
                let naive = am.objective(&tenants, &cfg);
                let fast = objective_with_tables(&am, &tenants, &tables, &cfg);
                assert!(
                    agree(fast, naive),
                    "seed {seed} {mode:?} {cfg:?}: delta {fast} vs naive {naive}"
                );
                // The full Evaluation aggregates must agree too.
                let ev = am.evaluate(&tenants, &cfg);
                assert!(
                    agree(fast, ev.objective),
                    "seed {seed} {mode:?}: delta {fast} vs evaluate() {}",
                    ev.objective
                );
                checked += 1;
            }
        }
    }
    assert!(checked >= 1000, "only {checked} configurations checked");
}

#[test]
fn prop_delta_move_scoring_matches_naive() {
    // Scoring a single-tenant move against cached state must equal the
    // naive objective of the moved configuration — including moves that
    // activate/deactivate tenants (λ^TPU changes), flip the overflow
    // regime, and reshuffle cores.
    let cost = CostModel::new(HardwareSpec::default());
    let mut checked = 0usize;
    for seed in 3000..3000 + 100u64 {
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let tables = PrefixTables::for_tenants(&cost, &tenants);
        for mode in MODES {
            let am = AnalyticModel::with_alpha_mode(cost.clone(), mode);
            let cfg = random_config(&mut rng, &tenants);
            let ev = DeltaEvaluator::new(&am, &tenants, &tables, &cfg);
            for _ in 0..4 {
                let m = rng.below(tenants.len());
                let new_p = rng.below(tenants[m].model.partition_points + 1);
                let mut new_cores = cfg.cores.clone();
                for k in new_cores.iter_mut() {
                    if rng.f64() < 0.3 {
                        *k = rng.below(4);
                    }
                }
                let (_, got) = ev.score_move(m, new_p, &new_cores);
                let mut moved = cfg.clone();
                moved.partitions[m] = new_p;
                moved.cores = new_cores;
                let naive = am.objective(&tenants, &moved);
                assert!(
                    agree(got, naive),
                    "seed {seed} {mode:?} move m={m}→{new_p}: delta {got} vs naive {naive}"
                );
                // And against a fresh table-backed build of the moved cfg.
                let fresh = objective_with_tables(&am, &tenants, &tables, &moved);
                assert!(agree(got, fresh), "seed {seed} {mode:?}: vs fresh build");
                checked += 1;
            }
        }
    }
    assert!(checked >= 1000, "only {checked} moves checked");
}

#[test]
fn prop_engine_hill_climb_matches_naive_reference() {
    // With strictly positive rates (no exact-tie no-op moves) the
    // incremental climb must take move-for-move the same trajectory as
    // the pre-engine implementation. (Zero-rate tenants can flip exact
    // float ties either way — both outcomes are valid local optima — so
    // they are exercised by the feasibility properties above instead.)
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    for seed in 4000..4000 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let k_max = 1 + rng.below(6);
        let fast = alloc::hill_climb(&am, &tenants, k_max);
        let slow = alloc::hill_climb_naive(&am, &tenants, k_max);
        assert_eq!(
            fast.config, slow.config,
            "seed {seed}: engine and naive climbs diverged"
        );
        assert_eq!(fast.evaluations, slow.evaluations, "seed {seed}");
        assert!(
            agree(fast.predicted_objective, slow.predicted_objective),
            "seed {seed}: {} vs {}",
            fast.predicted_objective,
            slow.predicted_objective
        );
    }
}

#[test]
fn prop_admission_matches_ground_truth_stability() {
    // Admission control must agree with the exhaustive reference solver:
    // a mix is refused iff NO constraint-feasible configuration has a
    // finite objective (ρ < 1 everywhere). Rates are scaled across a wide
    // range so both accept and reject regimes are exercised.
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    let mut accepted = 0usize;
    let mut rejected = 0usize;
    for seed in 5000..5000 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let mut tenants = random_tenants(&mut rng);
        tenants.truncate(3); // keep the exhaustive cross-check tractable
        let scale = 10f64.powf(rng.range_f64(-1.0, 3.0));
        for t in tenants.iter_mut() {
            t.rate *= scale;
        }
        let k_max = 1 + rng.below(4);
        let exact = alloc::exhaustive_best(&am, &tenants, k_max);
        let feasible = exact
            .as_ref()
            .map(|a| a.predicted_objective.is_finite())
            .unwrap_or(false);
        match alloc::admit(&am, &tenants, k_max) {
            Ok(plan) => {
                accepted += 1;
                assert!(
                    plan.predicted_objective.is_finite(),
                    "seed {seed}: admitted with diverged objective"
                );
                check_constraints(&tenants, &plan.config, k_max)
                    .unwrap_or_else(|e| panic!("seed {seed}: admitted infeasible config: {e}"));
                assert!(
                    feasible,
                    "seed {seed}: admitted a mix the exhaustive solver deems unstable"
                );
            }
            Err(e) => {
                rejected += 1;
                assert!(
                    e.predicted_objective.is_infinite(),
                    "seed {seed}: rejection must carry a diverged objective, got {}",
                    e.predicted_objective
                );
                assert_eq!(e.n_tenants, tenants.len(), "seed {seed}");
                assert!(
                    !feasible,
                    "seed {seed}: rejected a mix with a stable configuration \
                     (exhaustive found objective {:?})",
                    exact.map(|a| a.predicted_objective)
                );
            }
        }
    }
    // The rate sweep must actually exercise both regimes.
    assert!(accepted >= 3, "only {accepted} mixes accepted");
    assert!(rejected >= 3, "only {rejected} mixes rejected");
}

#[test]
fn prop_histogram_percentiles_monotone() {
    // For any recorded sample set, percentiles must be nondecreasing in
    // p (p50 <= p95 <= p99 <= p100) and the top percentile must sit at
    // or below the exact max (within one bucket's relative width).
    for seed in 6000..6000 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let mut h = LatencyHistogram::default();
        let n = 20 + rng.below(3000);
        for _ in 0..n {
            // log-uniform over ~6 decades, exercising many buckets
            h.record(10f64.powf(rng.range_f64(-5.0, 1.5)));
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        let p100 = h.percentile(100.0);
        assert!(p50 <= p95, "seed {seed}: p50 {p50} > p95 {p95}");
        assert!(p95 <= p99, "seed {seed}: p95 {p95} > p99 {p99}");
        assert!(p99 <= p100, "seed {seed}: p99 {p99} > p100 {p100}");
        assert!(
            p100 <= h.max() * 1.03,
            "seed {seed}: p100 {p100} above max {}",
            h.max()
        );
    }
}

#[test]
fn prop_histogram_merge_equals_record_all() {
    // Splitting a stream across two histograms and merging must be
    // indistinguishable from recording everything into one: identical
    // bucket counts make every percentile bit-equal, and the streaming
    // moments agree to float associativity.
    for seed in 6200..6200 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let mut all = LatencyHistogram::default();
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        let n = 10 + rng.below(2000);
        for _ in 0..n {
            let v = 10f64.powf(rng.range_f64(-5.0, 1.0));
            all.record(v);
            if rng.f64() < 0.5 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count(), "seed {seed}");
        for p in [10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            assert_eq!(
                a.percentile(p).to_bits(),
                all.percentile(p).to_bits(),
                "seed {seed}: p{p}"
            );
        }
        let rel = (a.mean() - all.mean()).abs() / all.mean().abs().max(1e-30);
        assert!(rel < 1e-9, "seed {seed}: merged mean off by {rel}");
        assert_eq!(a.max(), all.max(), "seed {seed}");
    }
}

#[test]
fn prop_rate_solver_hits_target_utilization() {
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    for seed in 1000..1000 + 20u64 {
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let cfg = Config::all_tpu(&tenants);
        let shares: Vec<f64> = tenants.iter().map(|_| rng.range_f64(0.5, 2.0)).collect();
        let rho = rng.range_f64(0.1, 0.8);
        let rates =
            swapless::workload::rates_for_utilization(&am, &tenants, &cfg, &shares, rho);
        let scaled: Vec<Tenant> = tenants
            .iter()
            .zip(&rates)
            .map(|(t, r)| Tenant {
                model: t.model.clone(),
                rate: *r,
            })
            .collect();
        let got = am.tpu_utilization(&scaled, &cfg);
        assert!(
            (got - rho).abs() < 0.02,
            "seed {seed}: target ρ={rho}, got {got}"
        );
    }
}

#[test]
fn prop_overload_conservation_and_capacity_bound() {
    // Satellite invariant of the bounded-admission layer: under ANY
    // overload policy and a random workload/config, every arrival
    // resolves exactly once — per class and per tenant,
    //   arrivals == completed + rejected + shed + expired
    // once the system fully drains (arrivals stop long before the
    // horizon; no churn, warmup 0). And with a bounded queue the TPU
    // station's instantaneous occupancy (queued + in-service) never
    // exceeds the capacity under any policy but Block.
    use swapless::sched::{OverloadPolicy, SloClass};
    use swapless::sim::Simulator;
    use swapless::workload::{generate_arrivals_annotated, RateSchedule};

    let cost = CostModel::new(HardwareSpec::default());
    const ARRIVAL_SPAN: f64 = 40.0;
    for (case, policy) in (0..24u64).flat_map(|c| {
        OverloadPolicy::ALL.into_iter().map(move |p| (c, p))
    }) {
        let seed = 5000 + case;
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let n = tenants.len();
        let cfg = Config {
            partitions: tenants
                .iter()
                .map(|t| rng.below(t.model.partition_points + 1))
                .collect(),
            cores: (0..n).map(|_| rng.below(3)).collect(),
        };
        let capacity = 1 + rng.below(8);
        let schedules: Vec<RateSchedule> = tenants
            .iter()
            .map(|t| RateSchedule::constant(t.rate))
            .collect();
        let classes: Vec<SloClass> = (0..n)
            .map(|_| SloClass::from_index(rng.below(3)).unwrap())
            .collect();
        let deadlines: Vec<Option<f64>> = (0..n)
            .map(|_| {
                if rng.f64() < 0.5 {
                    Some(rng.range_f64(0.001, 0.5))
                } else {
                    None
                }
            })
            .collect();
        let mut arr_rng = Rng::new(seed ^ 0xABCD);
        let arrivals = generate_arrivals_annotated(
            &schedules,
            &classes,
            &deadlines,
            ARRIVAL_SPAN,
            &mut arr_rng,
        );
        let mut sim = Simulator::new(
            &cost,
            &tenants,
            cfg,
            SimOptions {
                horizon: 5000.0,
                warmup: 0.0,
                seed,
                capacity: Some(capacity),
                overload: policy,
                ..SimOptions::default()
            },
        );
        let res = sim.run(&arrivals, None);
        assert_eq!(res.dropped, 0, "seed {seed} {policy}: churn-drops without churn");

        // Per-class conservation.
        for class in SloClass::ALL {
            let arrived = arrivals.iter().filter(|a| a.class == class).count() as u64;
            let resolved = res.per_class.get(class).count()
                + res.per_class.rejected(class)
                + res.per_class.shed(class)
                + res.per_class.expired(class);
            assert_eq!(
                resolved, arrived,
                "seed {seed} {policy} class {class}: {resolved} != {arrived}"
            );
            // Acceptance brackets: accepted covers everything that got
            // in, i.e. completions + post-acceptance drops (expired
            // splits across entry refusals and evictions).
            let accepted = res.per_class.accepted(class);
            let completed = res.per_class.get(class).count();
            assert!(accepted >= completed + res.per_class.shed(class));
            assert!(
                accepted
                    <= completed
                        + res.per_class.shed(class)
                        + res.per_class.expired(class)
            );
        }
        // Per-tenant conservation.
        for (m, stats) in res.per_model.iter().enumerate() {
            let arrived = arrivals.iter().filter(|a| a.model == m).count() as u64;
            assert_eq!(
                stats.completed + stats.rejected + stats.shed + stats.expired,
                arrived,
                "seed {seed} {policy} model {m}"
            );
        }
        // Occupancy bound (queued + in-service <= cap) for every bounded
        // policy; Block is the unbounded baseline.
        if policy != OverloadPolicy::Block {
            assert!(
                res.max_tpu_occupancy <= capacity,
                "seed {seed} {policy}: occupancy {} > cap {capacity}",
                res.max_tpu_occupancy
            );
        }
        // Drop-counter reachability: Block never drops anything; only
        // DeadlineDrop ever expires work. (`shed` can fire under Reject
        // and DeadlineDrop too — a TPU-accepted job refused at a full
        // internal CPU station counts as a mid-pipeline shed.)
        match policy {
            OverloadPolicy::Block => {
                assert_eq!(res.per_class.rejected_total(), 0);
                assert_eq!(res.per_class.shed_total(), 0);
                assert_eq!(res.per_class.expired_total(), 0);
            }
            OverloadPolicy::Reject | OverloadPolicy::ShedLowClass => {
                assert_eq!(res.per_class.expired_total(), 0);
            }
            OverloadPolicy::DeadlineDrop => {}
        }
    }
}

#[test]
fn prop_reject_wait_estimate_matches_analytic_helper() {
    // The typed Overloaded error's wait estimate is the queue's running
    // predicted-service sum divided across the station's servers — pin
    // it against the analytic layer's helper over random backlogs.
    use swapless::analytic::TenantHandle;
    use swapless::sched::{
        DisciplineKind, JobMeta, Offer, OverloadPolicy, RejectReason, SchedQueue, SloClass,
        StationLoad,
    };

    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(9000 + seed);
        let cap = 1 + rng.below(12);
        let servers = 1 + rng.below(4);
        let mut q: SchedQueue<u32> = SchedQueue::with_kind(DisciplineKind::Fifo);
        // Fill to capacity with random hints.
        for i in 0..cap {
            q.push(
                JobMeta {
                    tenant: TenantHandle(i as u64 % 3),
                    class: SloClass::Standard,
                    service_hint: rng.range_f64(1e-4, 0.05),
                    deadline: None,
                    device: 0,
                },
                i as u32,
            );
        }
        let backlog = q.queued_service_s();
        let offer = q.offer(
            JobMeta {
                tenant: TenantHandle(9),
                class: SloClass::Standard,
                service_hint: 0.01,
                deadline: None,
                device: 0,
            },
            999,
            0.0,
            "tpu",
            Some(cap),
            OverloadPolicy::Reject,
            StationLoad {
                in_service: 0,
                servers,
            },
        );
        match offer {
            Offer::Rejected {
                reason: RejectReason::Overloaded(o),
                ..
            } => {
                let expect = am.station_wait_estimate(backlog, servers);
                assert!(
                    (o.estimated_wait_s - expect).abs() < 1e-12,
                    "seed {seed}: {} vs {expect}",
                    o.estimated_wait_s
                );
                assert_eq!(o.queue_depth, cap);
                assert_eq!(o.capacity, cap);
            }
            _ => panic!("seed {seed}: full queue must reject"),
        }
    }
}

/// Random classed/deadlined arrival streams survive a full save→load
/// round trip through the on-disk v3 trace format — and synthesized
/// legacy v1/v2 files load with the documented defaults (Standard class,
/// no deadline). Exercises the actual file paths, not just the JSON
/// encoder.
#[test]
fn prop_trace_roundtrip_v1_v2_v3() {
    use swapless::sched::SloClass;
    use swapless::workload::trace;
    use swapless::workload::Arrival;

    let dir = std::env::temp_dir().join(format!(
        "swapless-trace-prop-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();

    for seed in 0..40u64 {
        let mut rng = Rng::new(7000 + seed);
        let n_models = 1 + rng.below(4);
        let names: Vec<String> = (0..n_models).map(|i| format!("m{i}")).collect();
        let n_arrivals = rng.below(60);
        let mut t = 0.0f64;
        let arrivals: Vec<Arrival> = (0..n_arrivals)
            .map(|_| {
                t += rng.range_f64(0.0, 0.5);
                let deadline = if rng.f64() < 0.5 {
                    Some(t + rng.range_f64(0.01, 2.0))
                } else {
                    None
                };
                Arrival {
                    time: t,
                    model: rng.below(n_models),
                    class: SloClass::from_index(rng.below(3)).unwrap(),
                    deadline,
                }
            })
            .collect();

        // v3: full fidelity through the real file path.
        let path = dir.join(format!("v3-{seed}.json"));
        let path = path.to_str().unwrap();
        trace::save(path, &arrivals, &names)
            .unwrap_or_else(|e| panic!("seed {seed}: save: {e}"));
        let (back, back_names) =
            trace::load(path).unwrap_or_else(|e| panic!("seed {seed}: load: {e}"));
        assert_eq!(back_names, names, "seed {seed}");
        assert_eq!(back, arrivals, "seed {seed}: v3 round trip not lossless");

        // v1 (two-element entries): classes/deadlines default.
        let v1_entries: Vec<String> = arrivals
            .iter()
            .map(|a| format!("[{}, {}]", a.time, a.model))
            .collect();
        let v1 = format!(
            r#"{{"version":1,"models":[{}],"arrivals":[{}]}}"#,
            names
                .iter()
                .map(|n| format!("{n:?}"))
                .collect::<Vec<_>>()
                .join(","),
            v1_entries.join(",")
        );
        let v1_path = dir.join(format!("v1-{seed}.json"));
        std::fs::write(&v1_path, &v1).unwrap();
        let (legacy, _) = trace::load(v1_path.to_str().unwrap())
            .unwrap_or_else(|e| panic!("seed {seed}: v1 load: {e}"));
        assert_eq!(legacy.len(), arrivals.len(), "seed {seed}");
        for (l, a) in legacy.iter().zip(&arrivals) {
            assert_eq!(l.model, a.model, "seed {seed}");
            assert!((l.time - a.time).abs() < 1e-9, "seed {seed}");
            assert_eq!(l.class, SloClass::Standard, "seed {seed}");
            assert_eq!(l.deadline, None, "seed {seed}");
        }

        // v2 (three-element classed entries): classes survive, deadlines
        // default.
        let v2_entries: Vec<String> = arrivals
            .iter()
            .map(|a| format!("[{}, {}, {}]", a.time, a.model, a.class.index()))
            .collect();
        let v2 = format!(
            r#"{{"version":2,"models":[{}],"arrivals":[{}]}}"#,
            names
                .iter()
                .map(|n| format!("{n:?}"))
                .collect::<Vec<_>>()
                .join(","),
            v2_entries.join(",")
        );
        let v2_path = dir.join(format!("v2-{seed}.json"));
        std::fs::write(&v2_path, &v2).unwrap();
        let (classed, _) = trace::load(v2_path.to_str().unwrap())
            .unwrap_or_else(|e| panic!("seed {seed}: v2 load: {e}"));
        for (l, a) in classed.iter().zip(&arrivals) {
            assert_eq!(l.class, a.class, "seed {seed}");
            assert_eq!(l.deadline, None, "seed {seed}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
