//! Sim-vs-live scheduling parity: the DES and the live server must drive
//! the SAME scheduling core. These tests pin it three ways:
//!
//! 1. one discipline *object* replays the DES's push/pop call pattern and
//!    the live server's and produces identical schedules;
//! 2. both consumers report the same `DisciplineKind` when built from the
//!    same selector, and under FIFO an identical workload completes with
//!    identical per-tenant counts on both paths (no drops, no failures);
//! 3. every discipline serves a live multi-tenant workload end-to-end
//!    (no deadlocks in the worker loops).

use std::time::Duration;

use swapless::analytic::{Config, Tenant, TenantHandle};
use swapless::config::HardwareSpec;
use swapless::coordinator::{AttachOptions, Request, RequestError, Server, ServerBuilder};
use swapless::model::{synthetic_model, Manifest};
use swapless::runtime::service::ExecBackend;
use swapless::sched::{DisciplineKind, JobMeta, OverloadPolicy, SchedQueue, SloClass};
use swapless::sim::{SimOptions, Simulator};
use swapless::tpu::CostModel;
use swapless::workload::Arrival;

fn builder() -> ServerBuilder {
    ServerBuilder::new(
        &Manifest::synthetic(),
        CostModel::new(HardwareSpec::default()),
    )
    .backend(ExecBackend::Emulated)
}

fn input_for(server: &Server, h: TenantHandle) -> Vec<f32> {
    let n: usize = server
        .model_meta(h)
        .expect("attached")
        .input_shape
        .iter()
        .product();
    vec![0.5; n]
}

/// The same discipline OBJECT is driven first with the call pattern the
/// DES uses (enqueue bursts between pops) and then with the live server's
/// (interleaved push/pop from the worker loop). Identical job sequences
/// must schedule identically — there is one scheduling core, not two.
#[test]
fn one_discipline_object_serves_both_call_patterns() {
    let jobs: Vec<JobMeta> = (0..12)
        .map(|i| JobMeta {
            tenant: TenantHandle(i % 3),
            class: SloClass::from_index((i % 3) as usize).unwrap(),
            service_hint: 0.010 + (i % 4) as f64 * 0.005,
            deadline: None,
            device: 0,
        })
        .collect();
    let mut q: SchedQueue<usize> = SchedQueue::with_kind(DisciplineKind::Fifo);

    // DES pattern: all arrivals enqueued, then the station drains.
    for (i, m) in jobs.iter().enumerate() {
        q.push(*m, i);
    }
    let mut des_order = Vec::new();
    while let Some((_, i)) = q.pop() {
        des_order.push(i);
    }

    // Live pattern on the SAME object: the worker pops while submits
    // trickle in (one pop after every push once the queue is warm).
    let mut live_order = Vec::new();
    for (i, m) in jobs.iter().enumerate() {
        q.push(*m, i);
        if i >= 3 {
            live_order.push(q.pop().unwrap().1);
        }
    }
    while let Some((_, i)) = q.pop() {
        live_order.push(i);
    }

    // FIFO: both call patterns yield arrival order exactly.
    assert_eq!(des_order, (0..12).collect::<Vec<usize>>());
    assert_eq!(live_order, (0..12).collect::<Vec<usize>>());
}

/// Under FIFO, an identical two-tenant workload driven through the DES
/// and through the live server (same discipline selector, same full-TPU
/// configuration) completes every request on both paths with matching
/// per-tenant counts — and both report the same `DisciplineKind` from
/// the shared factory.
#[test]
fn sim_vs_live_parity_under_fifo() {
    const PER_TENANT: usize = 20;

    // --- DES side ---------------------------------------------------
    let cost = CostModel::new(HardwareSpec::default());
    let tenants = vec![
        Tenant {
            model: synthetic_model("a", 4, 800_000, 300_000_000),
            rate: 2.0,
        },
        Tenant {
            model: synthetic_model("b", 5, 900_000, 350_000_000),
            rate: 2.0,
        },
    ];
    let cfg = Config::all_tpu(&tenants);
    let mut arrivals = Vec::new();
    for i in 0..PER_TENANT {
        for m in 0..2 {
            arrivals.push(Arrival {
                time: 0.05 * (2 * i + m) as f64,
                model: m,
                class: SloClass::Standard,
                deadline: None,
            });
        }
    }
    let mut sim = Simulator::new(
        &cost,
        &tenants,
        cfg,
        SimOptions {
            horizon: 1000.0,
            warmup: 0.0,
            seed: 1,
            discipline: DisciplineKind::Fifo,
            ..SimOptions::default()
        },
    );
    assert_eq!(sim.discipline(), DisciplineKind::Fifo);
    let res = sim.run(&arrivals, None);
    assert_eq!(res.dropped, 0);
    let sim_counts: Vec<u64> = res.per_model.iter().map(|m| m.completed).collect();
    assert_eq!(sim_counts, vec![PER_TENANT as u64; 2]);
    assert_eq!(res.per_class.total_count(), 2 * PER_TENANT as u64);

    // --- live side (same discipline selector, same shape) -----------
    let server = builder()
        .adaptive(false)
        .discipline(DisciplineKind::Fifo)
        .build()
        .unwrap();
    assert_eq!(server.discipline(), DisciplineKind::Fifo);
    let ha = server
        .attach("mobilenetv2", AttachOptions::default())
        .unwrap();
    let hb = server
        .attach("squeezenet", AttachOptions::default())
        .unwrap();
    // Full-TPU for both tenants: every request flows through the shared
    // TPU queue exactly like the DES run above.
    let pps: Vec<usize> = [ha, hb]
        .iter()
        .map(|h| server.model_meta(*h).unwrap().partition_points)
        .collect();
    server
        .set_config(Config {
            partitions: pps,
            cores: vec![0, 0],
        })
        .unwrap();

    let mut pending = Vec::new();
    for _ in 0..PER_TENANT {
        for h in [ha, hb] {
            pending.push((h, server.submit(h, input_for(&server, h))));
        }
    }
    let mut live_counts = [0u64; 2];
    for (h, ticket) in pending {
        let done = ticket.wait().unwrap();
        assert_eq!(done.tenant, h);
        live_counts[if h == ha { 0 } else { 1 }] += 1;
    }
    let stats = server.stats();
    assert_eq!(stats.failed, 0);
    assert_eq!(live_counts.to_vec(), sim_counts);
    assert_eq!(stats.tenant(ha).unwrap().latency.count(), PER_TENANT as u64);
    assert_eq!(stats.tenant(hb).unwrap().latency.count(), PER_TENANT as u64);
    // Per-class accounting agrees with the DES: everything Standard.
    assert_eq!(stats.per_class.total_count(), 2 * PER_TENANT as u64);
    assert_eq!(
        stats.per_class.get(SloClass::Standard).count(),
        res.per_class.get(SloClass::Standard).count()
    );
    assert_eq!(stats.per_class.get(SloClass::Interactive).count(), 0);
}

/// Every discipline drives the full live stack — mixed TPU/CPU split,
/// SLO-tagged tenants, per-request class overrides — without losing or
/// deadlocking requests.
#[test]
fn every_discipline_serves_live_traffic() {
    for kind in DisciplineKind::ALL {
        let server = builder().adaptive(false).discipline(kind).build().unwrap();
        assert_eq!(server.discipline(), kind);
        let ha = server
            .attach(
                "mobilenetv2",
                AttachOptions {
                    rate_hint: 2.0,
                    class: SloClass::Interactive,
                },
            )
            .unwrap();
        let hb = server
            .attach(
                "inceptionv4",
                AttachOptions {
                    rate_hint: 1.0,
                    class: SloClass::Batch,
                },
            )
            .unwrap();
        let mut pending = Vec::new();
        for i in 0..8 {
            pending.push(server.submit(ha, input_for(&server, ha)));
            if i % 2 == 0 {
                pending.push(server.submit(hb, input_for(&server, hb)));
            } else {
                // Per-request override lands in the overridden class.
                pending.push(server.submit(
                    hb,
                    Request::new(input_for(&server, hb)).with_class(SloClass::Standard),
                ));
            }
        }
        for ticket in pending {
            ticket.wait().unwrap_or_else(|e| panic!("{kind}: {e}"));
        }
        let stats = server.stats();
        assert_eq!(stats.failed, 0, "{kind}");
        assert_eq!(stats.completed, 16, "{kind}");
        assert_eq!(stats.per_class.get(SloClass::Interactive).count(), 8, "{kind}");
        assert_eq!(stats.per_class.get(SloClass::Batch).count(), 4, "{kind}");
        assert_eq!(stats.per_class.get(SloClass::Standard).count(), 4, "{kind}");
    }
}

/// Drop parity: the SAME deadline-annotated workload under `DeadlineDrop`
/// yields identical per-tenant accepted/rejected/dropped counts in the
/// DES and the live server. Tenant `a` carries a generous deadline
/// (every request completes); tenant `b`'s deadline is already hopeless
/// at submission (deadline = arrival time, positive service estimate),
/// so every request is deterministically expired at admission on both
/// paths — timing-independent, exact counts.
#[test]
fn sim_vs_live_drop_parity_under_deadline_drop() {
    const PER_TENANT: usize = 20;

    // --- DES side ---------------------------------------------------
    let cost = CostModel::new(HardwareSpec::default());
    let tenants = vec![
        Tenant {
            model: synthetic_model("a", 4, 800_000, 300_000_000),
            rate: 2.0,
        },
        Tenant {
            model: synthetic_model("b", 5, 900_000, 350_000_000),
            rate: 2.0,
        },
    ];
    let cfg = Config::all_tpu(&tenants);
    let mut arrivals = Vec::new();
    for i in 0..PER_TENANT {
        for m in 0..2 {
            let time = 0.05 * (2 * i + m) as f64 + 0.01;
            arrivals.push(Arrival {
                time,
                model: m,
                // a: generous absolute deadline; b: already hopeless.
                deadline: if m == 0 { Some(time + 1e6) } else { Some(time) },
                class: SloClass::Standard,
            });
        }
    }
    let mut sim = Simulator::new(
        &cost,
        &tenants,
        cfg,
        SimOptions {
            horizon: 1000.0,
            warmup: 0.0,
            seed: 1,
            discipline: DisciplineKind::Fifo,
            overload: OverloadPolicy::DeadlineDrop,
            ..SimOptions::default()
        },
    );
    let res = sim.run(&arrivals, None);
    let sim_accepted: Vec<u64> = res.per_model.iter().map(|m| m.accepted).collect();
    let sim_dropped: Vec<u64> = res.per_model.iter().map(|m| m.dropped()).collect();
    let sim_completed: Vec<u64> = res.per_model.iter().map(|m| m.completed).collect();
    assert_eq!(sim_accepted, vec![PER_TENANT as u64, 0]);
    assert_eq!(sim_dropped, vec![0, PER_TENANT as u64]);
    assert_eq!(sim_completed, vec![PER_TENANT as u64, 0]);
    assert_eq!(res.per_class.expired_total(), PER_TENANT as u64);
    assert_eq!(res.per_class.goodput_total(), PER_TENANT as u64);

    // --- live side (same policy, same shape) ------------------------
    let server = builder()
        .adaptive(false)
        .discipline(DisciplineKind::Fifo)
        .overload(OverloadPolicy::DeadlineDrop)
        .build()
        .unwrap();
    let ha = server
        .attach("mobilenetv2", AttachOptions::default())
        .unwrap();
    let hb = server
        .attach("squeezenet", AttachOptions::default())
        .unwrap();
    // Full-TPU for both tenants, exactly like the DES run.
    let pps: Vec<usize> = [ha, hb]
        .iter()
        .map(|h| server.model_meta(*h).unwrap().partition_points)
        .collect();
    server
        .set_config(Config {
            partitions: pps,
            cores: vec![0, 0],
        })
        .unwrap();
    let mut pending = Vec::new();
    for _ in 0..PER_TENANT {
        pending.push((
            ha,
            server.submit(
                ha,
                Request::new(input_for(&server, ha)).with_deadline(Duration::from_secs(3600)),
            ),
        ));
        pending.push((
            hb,
            server.submit(
                hb,
                Request::new(input_for(&server, hb)).with_deadline(Duration::ZERO),
            ),
        ));
    }
    let mut live_completed = [0u64; 2];
    let mut live_expired = [0u64; 2];
    for (h, ticket) in pending {
        match ticket.wait() {
            Ok(done) => {
                assert_eq!(done.tenant, h);
                live_completed[if h == ha { 0 } else { 1 }] += 1;
            }
            Err(RequestError::DeadlineExceeded { .. }) => {
                live_expired[if h == ha { 0 } else { 1 }] += 1;
            }
            Err(e) => panic!("unexpected failure: {e}"),
        }
    }
    let stats = server.stats();
    // Identical per-tenant accepted/dropped counts, DES vs live.
    let live_accepted: Vec<u64> = [ha, hb]
        .iter()
        .map(|h| stats.tenant(*h).unwrap().accepted)
        .collect();
    let live_dropped: Vec<u64> = [ha, hb]
        .iter()
        .map(|h| {
            let t = stats.tenant(*h).unwrap();
            t.rejected + t.dropped
        })
        .collect();
    assert_eq!(live_accepted, sim_accepted);
    assert_eq!(live_dropped, sim_dropped);
    assert_eq!(live_completed.to_vec(), sim_completed);
    assert_eq!(live_expired, [0, PER_TENANT as u64]);
    // Aggregate counters agree across engines too.
    assert_eq!(stats.expired, res.per_class.expired_total());
    assert_eq!(
        stats.per_class.accepted(SloClass::Standard),
        res.per_class.accepted(SloClass::Standard)
    );
    assert_eq!(stats.goodput(), res.per_class.goodput_total());
    assert_eq!(stats.failed, 0);
}
