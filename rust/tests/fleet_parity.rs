//! Sim-vs-live FLEET parity: the multi-device DES and the live fleet
//! router decompose into the same per-device engines under the same
//! placement, so an identical placement + workload must produce identical
//! per-device per-tenant accepted/completed counts on both paths.
//!
//! Construction mirrors `tests/sched_parity.rs`: a deterministic arrival
//! sequence (FIFO, Block overload — nothing drops) is replayed through
//! [`run_fleet`] and through a [`FleetServer`] whose tenants are pinned
//! to the same [`FleetPlan`] assignment with the same per-device (P, K)
//! configurations.

use swapless::analytic::Tenant;
use swapless::config::HardwareSpec;
use swapless::coordinator::AttachOptions;
use swapless::fleet::{place, run_fleet, Fleet, FleetServerBuilder};
use swapless::model::Manifest;
use swapless::runtime::service::ExecBackend;
use swapless::sched::SloClass;
use swapless::sim::SimOptions;
use swapless::workload::Arrival;

const MODELS: [&str; 3] = ["mobilenetv2", "squeezenet", "inceptionv4"];
const RATES: [f64; 3] = [3.0, 2.0, 1.0];
const PER_TENANT: usize = 15;

fn tenants() -> Vec<Tenant> {
    let manifest = Manifest::synthetic();
    MODELS
        .iter()
        .zip(&RATES)
        .map(|(n, r)| Tenant {
            model: manifest.get(n).unwrap().clone(),
            rate: *r,
        })
        .collect()
}

/// Round-robin deterministic arrivals: PER_TENANT requests per tenant,
/// globally interleaved and time-sorted.
fn arrivals() -> Vec<Arrival> {
    let mut out = Vec::new();
    for i in 0..PER_TENANT {
        for m in 0..MODELS.len() {
            out.push(Arrival {
                time: 0.05 * (MODELS.len() * i + m) as f64,
                model: m,
                class: SloClass::Standard,
                deadline: None,
            });
        }
    }
    out
}

#[test]
fn fleet_sim_vs_live_count_parity() {
    let ts = tenants();
    let fleet = Fleet::uniform(2, &HardwareSpec::default());
    let plan = place(&fleet, &ts);
    // The plan must use both devices for this mixed-size mix (the big
    // inceptionv4 prefix conflicts with co-residents).
    assert!(plan.devices.iter().all(|d| !d.tenants.is_empty()));

    // --- DES side ---------------------------------------------------
    let res = run_fleet(
        &fleet,
        &ts,
        &plan,
        &arrivals(),
        &SimOptions {
            horizon: 1000.0,
            warmup: 0.0,
            seed: 1,
            ..SimOptions::default()
        },
    );
    // FIFO + Block: every routed request is accepted and completes.
    for i in 0..MODELS.len() {
        assert_eq!(
            res.tenant_completed(i),
            PER_TENANT as u64,
            "DES lost requests of tenant {i}"
        );
    }
    let sim_per_device: Vec<Vec<(usize, u64, u64)>> = res
        .per_device
        .iter()
        .map(|d| {
            d.tenants
                .iter()
                .zip(&d.result.per_model)
                .map(|(&g, m)| (g, m.accepted, m.completed))
                .collect()
        })
        .collect();

    // --- live side: same placement, same per-device configs ---------
    let fs = FleetServerBuilder::new(&Manifest::synthetic(), fleet)
        .backend(ExecBackend::Emulated)
        .adaptive(false)
        .build()
        .unwrap();
    // Attach in per-device member order so each member server's
    // positional order matches the DES station's.
    let mut handle_of = vec![None; MODELS.len()];
    for dp in &plan.devices {
        for &g in &dp.tenants {
            let h = fs
                .attach_on(
                    MODELS[g],
                    AttachOptions {
                        rate_hint: RATES[g],
                        class: SloClass::Standard,
                    },
                    dp.device,
                )
                .unwrap();
            assert_eq!(fs.device_of(h), Some(dp.device));
            handle_of[g] = Some(h);
        }
        // Install the plan's exact (P, K) on the member server.
        fs.set_device_config(dp.device, dp.config.clone()).unwrap();
    }

    let mut pending = Vec::new();
    for a in arrivals() {
        let h = handle_of[a.model].unwrap();
        let n_in: usize = ts[a.model].model.input_shape.iter().product();
        pending.push((a.model, fs.submit(h, vec![0.5f32; n_in])));
    }
    let mut live_completed = vec![0u64; MODELS.len()];
    for (m, ticket) in pending {
        ticket.wait().unwrap_or_else(|e| panic!("tenant {m}: {e}"));
        live_completed[m] += 1;
    }

    let stats = fs.stats();
    assert_eq!(stats.failed(), 0);
    assert_eq!(stats.migrations, 0);
    // Identical per-device per-tenant accepted/completed counts.
    for (d, dev_stats) in stats.per_device.iter().enumerate() {
        for &(g, sim_accepted, sim_completed) in &sim_per_device[d] {
            let h = handle_of[g].unwrap();
            // The member server's handle differs from the fleet handle;
            // find its row by tenant name (one tenant per name here).
            let row = dev_stats
                .per_tenant
                .iter()
                .find(|t| t.name == MODELS[g])
                .unwrap_or_else(|| panic!("device {d} missing tenant {}", MODELS[g]));
            assert_eq!(
                row.accepted, sim_accepted,
                "device {d} tenant {g} accepted mismatch"
            );
            assert_eq!(
                row.latency.count(),
                sim_completed,
                "device {d} tenant {g} completed mismatch"
            );
            assert_eq!(live_completed[g], sim_completed);
            assert_eq!(fs.device_of(h), Some(d));
        }
    }
    // Aggregate parity: fleet totals agree with the DES totals.
    assert_eq!(stats.completed(), res.completed);
    assert_eq!(
        stats.per_class().get(SloClass::Standard).count(),
        res.completed
    );
}

#[test]
fn fleet_live_migration_preserves_every_ticket() {
    // Drain-then-move during live traffic: every submitted ticket
    // resolves (completion or typed error), nothing hangs, and the moved
    // tenant keeps serving on its new device.
    let fleet = Fleet::uniform(2, &HardwareSpec::default());
    let fs = FleetServerBuilder::new(&Manifest::synthetic(), fleet)
        .backend(ExecBackend::Emulated)
        .adaptive(false)
        .build()
        .unwrap();
    let ha = fs
        .attach_on("mobilenetv2", AttachOptions::default(), 0)
        .unwrap();
    let hb = fs
        .attach_on("squeezenet", AttachOptions::default(), 0)
        .unwrap();
    let manifest = Manifest::synthetic();
    let ia: usize = manifest
        .get("mobilenetv2")
        .unwrap()
        .input_shape
        .iter()
        .product();
    let ib: usize = manifest
        .get("squeezenet")
        .unwrap()
        .input_shape
        .iter()
        .product();
    // In-flight load on the source device while the migration runs.
    let mut pending = Vec::new();
    for _ in 0..8 {
        pending.push(fs.submit(ha, vec![0.5f32; ia]));
        pending.push(fs.submit(hb, vec![0.5f32; ib]));
    }
    assert!(fs.migrate(hb, 1).unwrap());
    for _ in 0..8 {
        pending.push(fs.submit(hb, vec![0.5f32; ib]));
    }
    let mut resolved = 0;
    for t in pending {
        // Completion or typed error — but never a hang or a panic.
        let _ = t.wait();
        resolved += 1;
    }
    assert_eq!(resolved, 24);
    let stats = fs.stats();
    assert_eq!(stats.migrations, 1);
    assert_eq!(fs.device_of(hb), Some(1));
    // Post-move traffic landed on device 1.
    assert!(stats.per_device[1].completed >= 8);
}
