//! Span conservation + profiled-cost-model parity, live and simulated.
//!
//! The tracing contract (telemetry module docs) pinned four ways:
//!
//! 1. DES property: across disciplines × overload policies on random
//!    workloads, every completed request of a sample-everything run
//!    flushes exactly one well-formed timeline — one `SpanQueue`,
//!    `SpanTpu` iff the partition has a TPU prefix, at most one
//!    `SpanSwap` (misses only), `SpanCpu` iff a CPU suffix ran — with
//!    monotone stamps, and the stage durations plus the boundary
//!    transfers (which spans deliberately exclude) reproduce the
//!    end-to-end latency exactly;
//! 2. live property: the wall-clock server upholds the same
//!    conservation across the same matrix, where the stage sum itself
//!    telescopes to the end-to-end time (the live path has no separate
//!    transfer stations — every instant between admission and
//!    completion lands in exactly one stage);
//! 3. live calibration: collector estimates from a sampled run override
//!    exactly the observed (device, tenant, partition) prefix-table
//!    entries, verbatim, and leave every unobserved entry analytic;
//! 4. closing the loop: a [`ProfiledCostModel`] calibrated from spans
//!    the DES generated (whose virtual service draws ARE the analytic
//!    values) rebuilds every tenant's tables bit-identically to the
//!    analytic [`PrefixTables`], across full-TPU, split, and all-CPU
//!    partition shapes.

use std::collections::BTreeMap;
use std::path::PathBuf;

use swapless::analytic::{Config, Tenant};
use swapless::config::HardwareSpec;
use swapless::coordinator::{AttachOptions, ServerBuilder};
use swapless::eventlog::{read_all, Event, EventLog};
use swapless::model::{synthetic_model, Manifest};
use swapless::runtime::service::ExecBackend;
use swapless::sched::{DisciplineKind, OverloadPolicy, SloClass};
use swapless::sim::{SimOptions, Simulator};
use swapless::telemetry::{ProfiledCostModel, Stage};
use swapless::tpu::{CostModel, PrefixTables};
use swapless::util::rng::Rng;
use swapless::workload::{generate_arrivals_annotated, RateSchedule};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("swapless-{name}-{}.log", std::process::id()))
}

/// One reassembled span timeline: per-stage record counts, stamps, and
/// the stage-duration sum.
#[derive(Debug)]
struct Timeline {
    tenant: u64,
    p: usize,
    count: [usize; Stage::COUNT],
    stamp: [f64; Stage::COUNT],
    sum: f64,
}

/// Regroup `Span*` records by (device, span id), checking per-record
/// invariants (non-negative durations, stable tenant/partition labels)
/// along the way.
fn collect_timelines(events: &[Event]) -> BTreeMap<(u16, u32), Timeline> {
    let mut out: BTreeMap<(u16, u32), Timeline> = BTreeMap::new();
    for e in events {
        let Some(stage) = Stage::from_kind(e.kind) else {
            continue;
        };
        assert!(e.value >= 0.0, "negative {} duration {}", stage.name(), e.value);
        let tl = out.entry((e.device, e.span_id())).or_insert(Timeline {
            tenant: e.span_tenant(),
            p: e.aux as usize,
            count: [0; Stage::COUNT],
            stamp: [f64::NAN; Stage::COUNT],
            sum: 0.0,
        });
        assert_eq!(tl.tenant, e.span_tenant(), "span id regrouped across tenants");
        assert_eq!(tl.p, e.aux as usize, "span id regrouped across partitions");
        tl.count[stage.index()] += 1;
        tl.stamp[stage.index()] = e.t;
        tl.sum += e.value;
    }
    out
}

impl Timeline {
    fn count_of(&self, s: Stage) -> usize {
        self.count[s.index()]
    }

    fn stamp_of(&self, s: Stage) -> f64 {
        self.stamp[s.index()]
    }

    /// Structural emission rules + stamp monotonicity for a timeline
    /// executed at partition `self.p` of a model with `p_max` points.
    fn check_structure(&self, p_max: usize, tag: &str) {
        assert_eq!(self.count_of(Stage::Queued), 1, "{tag}: SpanQueue count");
        if self.p > 0 {
            assert_eq!(self.count_of(Stage::Tpu), 1, "{tag}: SpanTpu count (p > 0)");
            assert!(self.count_of(Stage::Swap) <= 1, "{tag}: multiple SpanSwap");
        } else {
            assert_eq!(self.count_of(Stage::Tpu), 0, "{tag}: SpanTpu on p = 0");
            assert_eq!(self.count_of(Stage::Swap), 0, "{tag}: SpanSwap on p = 0");
        }
        let want_cpu = usize::from(self.p < p_max);
        assert_eq!(self.count_of(Stage::Cpu), want_cpu, "{tag}: SpanCpu count");

        let start = self.stamp_of(Stage::Queued);
        assert!(start.is_finite(), "{tag}: no admission anchor");
        if self.count_of(Stage::Tpu) == 1 {
            let tpu_end = self.stamp_of(Stage::Tpu);
            assert!(start <= tpu_end, "{tag}: TPU stamp precedes admission");
            if self.count_of(Stage::Swap) == 1 {
                assert_eq!(
                    self.stamp_of(Stage::Swap),
                    tpu_end,
                    "{tag}: swap and tpu must share the service-end stamp"
                );
            }
            if self.count_of(Stage::Cpu) == 1 {
                assert!(tpu_end <= self.stamp_of(Stage::Cpu), "{tag}: CPU before TPU");
            }
        }
        if self.count_of(Stage::Cpu) == 1 {
            assert!(
                start <= self.stamp_of(Stage::Cpu),
                "{tag}: completion precedes admission"
            );
        }
    }
}

fn random_tenants(rng: &mut Rng) -> Vec<Tenant> {
    let n = 2 + rng.below(3);
    (0..n)
        .map(|i| {
            let segs = 2 + rng.below(8);
            let mb_total = rng.range_f64(1.0, 30.0);
            let gflops = rng.range_f64(0.2, 8.0);
            Tenant {
                model: synthetic_model(
                    &format!("m{i}"),
                    segs,
                    (mb_total * 1e6 / segs as f64) as u64,
                    (gflops * 1e9 / segs as f64) as u64,
                ),
                rate: rng.range_f64(0.5, 5.0),
            }
        })
        .collect()
}

/// DES conservation property: one exact timeline per completion, for
/// every discipline × overload policy, on random workloads and random
/// (constraint-consistent) configurations.
#[test]
fn prop_des_span_conservation_across_disciplines_and_policies() {
    const ARRIVAL_SPAN: f64 = 20.0;
    let path = tmp("span-des");
    let cost = CostModel::new(HardwareSpec::default());
    for (case, (discipline, policy)) in DisciplineKind::ALL
        .into_iter()
        .flat_map(|d| OverloadPolicy::ALL.into_iter().map(move |p| (d, p)))
        .enumerate()
    {
        let seed = 5600 + case as u64;
        let tag = format!("seed {seed} {discipline} {policy}");
        let mut rng = Rng::new(seed);
        let tenants = random_tenants(&mut rng);
        let partitions: Vec<usize> = tenants
            .iter()
            .map(|t| rng.below(t.model.partition_points + 1))
            .collect();
        let cores: Vec<usize> = partitions
            .iter()
            .zip(&tenants)
            .map(|(&p, t)| {
                if p == t.model.partition_points {
                    0
                } else {
                    1 + rng.below(2)
                }
            })
            .collect();
        let cfg = Config { partitions: partitions.clone(), cores };
        let schedules: Vec<RateSchedule> = tenants
            .iter()
            .map(|t| RateSchedule::constant(t.rate))
            .collect();
        let classes: Vec<SloClass> = (0..tenants.len())
            .map(|_| SloClass::from_index(rng.below(3)).unwrap())
            .collect();
        let deadlines: Vec<Option<f64>> = (0..tenants.len())
            .map(|_| {
                if rng.f64() < 0.5 {
                    Some(rng.range_f64(0.005, 0.5))
                } else {
                    None
                }
            })
            .collect();
        let mut arr_rng = Rng::new(seed ^ 0x5AA5);
        let arrivals = generate_arrivals_annotated(
            &schedules,
            &classes,
            &deadlines,
            ARRIVAL_SPAN,
            &mut arr_rng,
        );

        let log = EventLog::create(&path).unwrap();
        let mut sim = Simulator::new(
            &cost,
            &tenants,
            cfg,
            SimOptions {
                horizon: 5000.0,
                warmup: 0.0,
                seed,
                discipline,
                capacity: Some(1 + rng.below(8)),
                overload: policy,
                span_sample: 1,
                log: Some(log.clone()),
                ..SimOptions::default()
            },
        );
        let res = sim.run(&arrivals, None);
        log.close();
        assert_eq!(log.dropped(), 0, "{tag}: bounded channel overflowed");
        let events = read_all(&path).unwrap();

        let completed: u64 = res.per_model.iter().map(|m| m.completed).sum();
        assert!(completed > 0, "{tag}: workload too small");
        let timelines = collect_timelines(&events);
        assert_eq!(
            timelines.len() as u64,
            completed,
            "{tag}: one timeline per completion"
        );

        let tables: Vec<PrefixTables> = tenants
            .iter()
            .map(|t| PrefixTables::new(&cost, &t.model))
            .collect();
        for ((_, id), tl) in &timelines {
            let i = tl.tenant as usize;
            let p_max = tenants[i].model.partition_points;
            let tag = format!("{tag} span {id}");
            assert_eq!(tl.p, partitions[i], "{tag}: partition label");
            tl.check_structure(p_max, &tag);
            // Exact accounting: stage sum + the boundary transfers the
            // spans deliberately exclude == the timeline's extent, in
            // all three partition shapes. Full-TPU timelines end at the
            // TPU stamp (the output transfer back to the host happens
            // after it), CPU-leg timelines at the completion stamp.
            let start = tl.stamp_of(Stage::Queued);
            let (end, transfers) = if tl.p == 0 {
                (tl.stamp_of(Stage::Cpu), 0.0)
            } else if tl.p < p_max {
                (
                    tl.stamp_of(Stage::Cpu),
                    tables[i].input_transfer() + tables[i].output_transfer(tl.p),
                )
            } else {
                (tl.stamp_of(Stage::Tpu), tables[i].input_transfer())
            };
            let extent = end - start;
            assert!(
                (tl.sum + transfers - extent).abs() < 1e-9,
                "{tag}: stages {} + transfers {transfers} != extent {extent}",
                tl.sum
            );
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// Live conservation property: the wall-clock server upholds the same
/// contract across the same discipline × policy matrix. Here the stage
/// sum telescopes to the full end-to-end time — queue waits run from
/// each push to the matching pop and services from pop to their end
/// stamp, so no instant between admission and the last stamp is
/// unaccounted.
#[test]
fn live_span_conservation_across_disciplines_and_policies() {
    const BURSTS: usize = 8;
    const BURST: usize = 12;
    let path = tmp("span-live");
    for (discipline, policy) in DisciplineKind::ALL
        .into_iter()
        .flat_map(|d| OverloadPolicy::ALL.into_iter().map(move |p| (d, p)))
    {
        let tag = format!("{discipline} {policy}");
        let log = EventLog::create(&path).unwrap();
        let server = ServerBuilder::new(
            &Manifest::synthetic(),
            CostModel::new(HardwareSpec::default()),
        )
        .backend(ExecBackend::Emulated)
        .adaptive(false)
        .discipline(discipline)
        .overload(policy)
        .queue_capacity(6)
        .span_sample(1)
        .log(log.clone())
        .build()
        .unwrap();
        let handles = [
            server.attach("mobilenetv2", AttachOptions::default()).unwrap(),
            server.attach("squeezenet", AttachOptions::default()).unwrap(),
        ];
        let p_max: Vec<usize> = handles
            .iter()
            .map(|&h| server.model_meta(h).unwrap().partition_points)
            .collect();
        let inputs: Vec<Vec<f32>> = handles
            .iter()
            .map(|&h| {
                let n: usize = server.model_meta(h).unwrap().input_shape.iter().product();
                vec![0.5f32; n]
            })
            .collect();

        // Bursts wider than the queue bound, so every policy actually
        // exercises its refusal path while completions accumulate.
        let mut ok = 0u64;
        for round in 0..BURSTS {
            let tickets: Vec<_> = (0..BURST)
                .map(|i| {
                    let which = (round + i) % 2;
                    server.submit(handles[which], inputs[which].clone())
                })
                .collect();
            for t in tickets {
                if t.wait().is_ok() {
                    ok += 1;
                }
            }
        }
        let stats = server.stats();
        drop(server);
        log.close();
        assert_eq!(log.dropped(), 0, "{tag}: bounded channel overflowed");
        assert_eq!(stats.completed, ok, "{tag}: ticket/counter mismatch");
        assert!(ok > 0, "{tag}: nothing completed");

        let events = read_all(&path).unwrap();
        let timelines = collect_timelines(&events);
        assert_eq!(
            timelines.len() as u64,
            ok,
            "{tag}: one timeline per completed request"
        );
        for ((_, id), tl) in &timelines {
            let tag = format!("{tag} span {id}");
            let pm = p_max[tl.tenant as usize];
            tl.check_structure(pm, &tag);
            let start = tl.stamp_of(Stage::Queued);
            let end = if tl.p < pm {
                tl.stamp_of(Stage::Cpu)
            } else {
                tl.stamp_of(Stage::Tpu)
            };
            let e2e = end - start;
            assert!(
                (tl.sum - e2e).abs() < 1e-6,
                "{tag}: stage sum {} leaves a gap against e2e {e2e}",
                tl.sum
            );
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// Live calibration: collector estimates from a sample-everything run
/// override exactly the observed prefix-table entries (verbatim copies
/// of the estimates) and leave every unobserved entry analytic.
#[test]
fn live_spans_calibrate_profiled_tables() {
    let cost = CostModel::new(HardwareSpec::default());
    let server = ServerBuilder::new(&Manifest::synthetic(), cost.clone())
        .backend(ExecBackend::Emulated)
        .adaptive(false)
        .span_sample(1)
        .build()
        .unwrap();
    let h = server.attach("mobilenetv2", AttachOptions::default()).unwrap();
    let meta = server.model_meta(h).unwrap();
    let n: usize = meta.input_shape.iter().product();
    let input = vec![0.5f32; n];
    for _ in 0..60 {
        server.submit(h, input.clone()).wait().unwrap();
    }

    let est = server.span_estimates();
    assert!(!est.is_empty(), "sample-everything run produced no estimates");
    let pm = ProfiledCostModel::from_collector(cost.clone(), &server.span_collector());
    assert_eq!(pm.calibrated_points(), est.len());

    let analytic = PrefixTables::new(&cost, &meta);
    let profiled = pm.tables(0, h.0, &meta);
    let mut overridden = 0usize;
    for p in 0..=meta.partition_points {
        match est.get(&(0u16, h.0 & 0xFFFF_FFFF, p as u16)) {
            Some(e) => {
                if p > 0 {
                    if let Some(s) = e.stage(Stage::Tpu) {
                        assert_eq!(profiled.tpu_service(p), s.estimate(), "tpu p={p}");
                        overridden += 1;
                    }
                    if let Some(s) = e.stage(Stage::Swap) {
                        assert_eq!(profiled.load_time(p), s.estimate(), "swap p={p}");
                    }
                }
                if p < meta.partition_points {
                    if let Some(s) = e.stage(Stage::Cpu) {
                        assert_eq!(profiled.cpu_service(p), s.estimate(), "cpu p={p}");
                        overridden += 1;
                    }
                }
            }
            None => {
                assert_eq!(profiled.tpu_service(p), analytic.tpu_service(p));
                assert_eq!(profiled.cpu_service(p), analytic.cpu_service(p));
                assert_eq!(profiled.load_time(p), analytic.load_time(p));
            }
        }
    }
    assert!(overridden > 0, "no measured override landed in the tables");
}

/// Closing the loop: a profiled model calibrated from DES-generated
/// spans — whose virtual service draws ARE the analytic values —
/// rebuilds every tenant's prefix tables bit-identically to the
/// analytic ones, across full-TPU, split, and all-CPU shapes.
#[test]
fn profiled_model_rebuilds_analytic_tables_from_des_spans() {
    const ARRIVAL_SPAN: f64 = 30.0;
    let path = tmp("span-oracle");
    let cost = CostModel::new(HardwareSpec::default());
    let tenants = vec![
        Tenant {
            model: synthetic_model("full", 4, 800_000, 300_000_000),
            rate: 3.0,
        },
        Tenant {
            model: synthetic_model("split", 5, 900_000, 350_000_000),
            rate: 2.0,
        },
        Tenant {
            model: synthetic_model("cpu", 3, 600_000, 250_000_000),
            rate: 2.0,
        },
    ];
    let cfg = Config {
        partitions: vec![4, 2, 0],
        cores: vec![0, 2, 2],
    };
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let classes = vec![SloClass::Standard; 3];
    let deadlines = vec![None; 3];
    let mut rng = Rng::new(77);
    let arrivals =
        generate_arrivals_annotated(&schedules, &classes, &deadlines, ARRIVAL_SPAN, &mut rng);

    let log = EventLog::create(&path).unwrap();
    let mut sim = Simulator::new(
        &cost,
        &tenants,
        cfg,
        SimOptions {
            horizon: 5000.0,
            warmup: 0.0,
            seed: 77,
            span_sample: 1,
            log: Some(log.clone()),
            ..SimOptions::default()
        },
    );
    let res = sim.run(&arrivals, None);
    log.close();
    assert_eq!(log.dropped(), 0);
    assert!(res.per_model.iter().all(|m| m.completed > 10), "undertrained oracle");
    let events = read_all(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    let pm = ProfiledCostModel::from_events(cost.clone(), &events);
    assert!(
        pm.calibrated_points() >= tenants.len(),
        "expected at least one calibration point per tenant, got {}",
        pm.calibrated_points()
    );
    for (i, t) in tenants.iter().enumerate() {
        let analytic = PrefixTables::new(&cost, &t.model);
        let profiled = pm.tables(0, i as u64, &t.model);
        for p in 0..=t.model.partition_points {
            assert_eq!(profiled.tpu_service(p), analytic.tpu_service(p), "tenant {i} tpu p={p}");
            assert_eq!(profiled.cpu_service(p), analytic.cpu_service(p), "tenant {i} cpu p={p}");
            assert_eq!(profiled.load_time(p), analytic.load_time(p), "tenant {i} load p={p}");
        }
    }
}
