//! Bit-exactness of the calendar event queue against the reference heap.
//!
//! Both [`QueueKind`]s implement the same strict total order — ascending
//! `(time, seq)` — so a simulation must produce *identical* results on
//! either, down to the last bit of every float. This suite pins that
//! across workload shapes, scheduling disciplines, overload policies,
//! fault plans, online reconfiguration, and tenant churn, plus the
//! parallel-replication merge path against a sequential seed loop.

use swapless::analytic::{AnalyticModel, Config, Tenant};
use swapless::config::HardwareSpec;
use swapless::fault::FaultPlan;
use swapless::metrics::LatencyHistogram;
use swapless::model::synthetic_model;
use swapless::sched::{DisciplineKind, OverloadPolicy, SloClass};
use swapless::sim::reconfig::SwapLessPolicy;
use swapless::sim::{
    merge_replications, replication_seed, simulate, simulate_churn, simulate_dynamic,
    simulate_replicated, ChurnEvent, ChurnKind, ModelStats, QueueKind, SimOptions, SimResult,
    Simulator,
};
use swapless::tpu::CostModel;
use swapless::util::rng::Rng;
use swapless::workload::{generate_arrivals_annotated, Arrival, RateSchedule};

fn assert_hist_eq(a: &LatencyHistogram, b: &LatencyHistogram, what: &str) {
    assert_eq!(a.count(), b.count(), "{what}: sample count");
    if a.count() == 0 {
        return;
    }
    assert_eq!(a.mean().to_bits(), b.mean().to_bits(), "{what}: mean");
    assert_eq!(a.std_dev().to_bits(), b.std_dev().to_bits(), "{what}: std_dev");
    assert_eq!(a.max().to_bits(), b.max().to_bits(), "{what}: max");
    for p in [50.0, 90.0, 95.0, 99.0] {
        assert_eq!(
            a.percentile(p).to_bits(),
            b.percentile(p).to_bits(),
            "{what}: p{p}"
        );
    }
}

fn assert_stats_eq(a: &ModelStats, b: &ModelStats, what: &str) {
    assert_eq!(a.handle, b.handle, "{what}: handle");
    assert_eq!(a.name, b.name, "{what}: name");
    assert_eq!(a.completed, b.completed, "{what}: completed");
    assert_eq!(a.accepted, b.accepted, "{what}: accepted");
    assert_eq!(a.rejected, b.rejected, "{what}: rejected");
    assert_eq!(a.shed, b.shed, "{what}: shed");
    assert_eq!(a.expired, b.expired, "{what}: expired");
    assert_hist_eq(&a.latency, &b.latency, what);
    assert_eq!(a.tpu_share.count(), b.tpu_share.count(), "{what}: tpu_share n");
    if a.tpu_share.count() > 0 {
        assert_eq!(
            a.tpu_share.mean().to_bits(),
            b.tpu_share.mean().to_bits(),
            "{what}: tpu_share mean"
        );
    }
}

/// Full bitwise comparison of two [`SimResult`]s. Reconfiguration
/// entries compare `(time, config)` only — the third element is the
/// wall-clock decision cost, which legitimately differs between runs.
fn assert_result_eq(a: &SimResult, b: &SimResult, what: &str) {
    assert_eq!(a.per_model.len(), b.per_model.len(), "{what}: tenant count");
    for (i, (x, y)) in a.per_model.iter().zip(&b.per_model).enumerate() {
        assert_stats_eq(x, y, &format!("{what}: per_model[{i}]"));
    }
    assert_eq!(a.retired.len(), b.retired.len(), "{what}: retired count");
    for (i, (x, y)) in a.retired.iter().zip(&b.retired).enumerate() {
        assert_stats_eq(x, y, &format!("{what}: retired[{i}]"));
    }
    assert_eq!(a.dropped, b.dropped, "{what}: dropped");
    assert_eq!(a.churn_log, b.churn_log, "{what}: churn_log");
    assert_eq!(
        a.mean_latency.to_bits(),
        b.mean_latency.to_bits(),
        "{what}: mean_latency"
    );
    assert_eq!(
        a.tpu_utilization.to_bits(),
        b.tpu_utilization.to_bits(),
        "{what}: tpu_utilization"
    );
    assert_eq!(
        a.cache_hit_rate.to_bits(),
        b.cache_hit_rate.to_bits(),
        "{what}: cache_hit_rate"
    );
    assert_eq!(a.reconfigs.len(), b.reconfigs.len(), "{what}: reconfig count");
    for (i, ((ta, ca, _), (tb, cb, _))) in a.reconfigs.iter().zip(&b.reconfigs).enumerate() {
        assert_eq!(ta.to_bits(), tb.to_bits(), "{what}: reconfig[{i}] time");
        assert_eq!(ca, cb, "{what}: reconfig[{i}] config");
    }
    for class in SloClass::ALL {
        let tag = format!("{what}: class {}", class.name());
        assert_eq!(a.per_class.accepted(class), b.per_class.accepted(class), "{tag} accepted");
        assert_eq!(a.per_class.rejected(class), b.per_class.rejected(class), "{tag} rejected");
        assert_eq!(a.per_class.shed(class), b.per_class.shed(class), "{tag} shed");
        assert_eq!(a.per_class.expired(class), b.per_class.expired(class), "{tag} expired");
        assert_eq!(a.per_class.missed(class), b.per_class.missed(class), "{tag} missed");
        assert_eq!(a.per_class.retried(class), b.per_class.retried(class), "{tag} retried");
        assert_hist_eq(a.per_class.get(class), b.per_class.get(class), &tag);
    }
    assert_eq!(a.max_tpu_occupancy, b.max_tpu_occupancy, "{what}: occupancy");
    assert_eq!(a.attempted, b.attempted, "{what}: attempted");
    assert_eq!(a.retried, b.retried, "{what}: retried");
    assert_eq!(a.failed, b.failed, "{what}: failed");
    assert_eq!(a.events, b.events, "{what}: events");
}

fn setup() -> (CostModel, Vec<Tenant>, Config) {
    let cost = CostModel::new(HardwareSpec::default());
    let tenants = vec![
        Tenant {
            model: synthetic_model("a", 6, 1_000_000, 500_000_000),
            rate: 40.0,
        },
        Tenant {
            model: synthetic_model("b", 6, 2_000_000, 700_000_000),
            rate: 25.0,
        },
        Tenant {
            model: synthetic_model("c", 6, 500_000, 300_000_000),
            rate: 15.0,
        },
    ];
    // Mixed placement: one split tenant with CPU suffix, one all-TPU,
    // one mostly-CPU — exercises every station type.
    let cfg = Config {
        partitions: vec![4, 6, 3],
        cores: vec![1, 0, 2],
    };
    (cost, tenants, cfg)
}

/// Class- and deadline-annotated arrivals for the tenant mix.
fn arrivals(tenants: &[Tenant], horizon: f64, seed: u64) -> Vec<Arrival> {
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let classes = [SloClass::Interactive, SloClass::Standard, SloClass::Batch];
    let deadlines = [Some(0.08), None, Some(0.25)];
    let mut rng = Rng::new(seed);
    generate_arrivals_annotated(&schedules, &classes, &deadlines, horizon, &mut rng)
}

fn opts(kind: QueueKind) -> SimOptions {
    SimOptions {
        horizon: 40.0,
        warmup: 2.0,
        seed: 9,
        queue: kind,
        ..SimOptions::default()
    }
}

#[test]
fn bit_exact_across_disciplines_and_overload_policies() {
    let (cost, tenants, cfg) = setup();
    let arrivals = arrivals(&tenants, 40.0, 9);
    for discipline in DisciplineKind::ALL {
        for overload in OverloadPolicy::ALL {
            let capacity = if overload == OverloadPolicy::Block {
                None
            } else {
                Some(8)
            };
            let what = format!("{}/{}", discipline.name(), overload.name());
            let mut results = Vec::new();
            for kind in QueueKind::ALL {
                let o = SimOptions {
                    discipline,
                    overload,
                    capacity,
                    ..opts(kind)
                };
                let mut sim = Simulator::new(&cost, &tenants, cfg.clone(), o);
                results.push(sim.run(&arrivals, None));
            }
            assert_result_eq(&results[0], &results[1], &what);
            // The matrix must exercise real traffic, not degenerate runs.
            assert!(results[0].per_model.iter().any(|m| m.completed > 0), "{what}: no completions");
        }
    }
}

#[test]
fn bit_exact_under_fault_plans() {
    let (cost, tenants, cfg) = setup();
    let plan = FaultPlan::new(5)
        .crash(0, 10.0, Some(18.0))
        .transient(0, 22.0, 30.0, 0.3)
        .slow_down(0, 32.0, 38.0, 3.0);
    let arrivals = arrivals(&tenants, 40.0, 13);
    let mut results = Vec::new();
    for kind in QueueKind::ALL {
        let o = SimOptions {
            faults: Some(plan.clone()),
            ..opts(kind)
        };
        let mut sim = Simulator::new(&cost, &tenants, cfg.clone(), o);
        results.push(sim.run(&arrivals, None));
    }
    assert_result_eq(&results[0], &results[1], "faulty run");
    assert!(results[0].retried > 0, "transient window never fired");
}

#[test]
fn bit_exact_under_online_reconfiguration() {
    let (cost, tenants, cfg) = setup();
    let am = AnalyticModel::new(cost.clone());
    // Rates swing enough to trip the SwapLess re-planner repeatedly.
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .enumerate()
        .map(|(i, t)| {
            RateSchedule::stepped(vec![
                (0.0, t.rate),
                (20.0, t.rate * if i == 0 { 2.5 } else { 0.4 }),
                (40.0, t.rate),
            ])
        })
        .collect();
    let mut results = Vec::new();
    for kind in QueueKind::ALL {
        let mut policy = SwapLessPolicy::new(am.clone(), 4, tenants.len(), 10.0, 5.0, 0.10);
        let o = SimOptions {
            horizon: 60.0,
            ..opts(kind)
        };
        results.push(simulate_dynamic(
            &cost, &tenants, &cfg, &schedules, &mut policy, o,
        ));
    }
    assert_result_eq(&results[0], &results[1], "dynamic run");
    assert!(!results[0].reconfigs.is_empty(), "policy never reconfigured");
}

#[test]
fn bit_exact_under_tenant_churn() {
    let (cost, tenants, cfg) = setup();
    let am = AnalyticModel::new(cost.clone());
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let mut results = Vec::new();
    for kind in QueueKind::ALL {
        let churn = vec![
            ChurnEvent {
                time: 15.0,
                kind: ChurnKind::Attach {
                    tenant: Tenant {
                        model: synthetic_model("d", 6, 1_500_000, 400_000_000),
                        rate: 12.0,
                    },
                    schedule: RateSchedule::constant(12.0),
                },
            },
            ChurnEvent {
                time: 35.0,
                kind: ChurnKind::Detach { name: "b".into() },
            },
        ];
        let mut policy = SwapLessPolicy::new(am.clone(), 4, tenants.len(), 10.0, 5.0, 0.10);
        let o = SimOptions {
            horizon: 50.0,
            ..opts(kind)
        };
        results.push(simulate_churn(
            &cost, &tenants, &cfg, &schedules, churn, &mut policy, o,
        ));
    }
    assert_result_eq(&results[0], &results[1], "churn run");
    assert_eq!(results[0].retired.len(), 1, "detach never retired a tenant");
}

/// The threaded replication path must equal a plain sequential seed loop
/// pushed through the same merge operator.
#[test]
fn replicated_merge_matches_sequential_loop() {
    let (cost, tenants, cfg) = setup();
    let base = SimOptions {
        horizon: 30.0,
        warmup: 2.0,
        seed: 21,
        ..SimOptions::default()
    };
    let n_reps = 4;
    let sequential: Vec<SimResult> = (0..n_reps)
        .map(|rep| {
            simulate(
                &cost,
                &tenants,
                &cfg,
                SimOptions {
                    seed: replication_seed(base.seed, rep),
                    ..base.clone()
                },
            )
        })
        .collect();
    let merged = merge_replications(sequential);
    let threaded = simulate_replicated(&cost, &tenants, &cfg, &base, n_reps);

    assert_eq!(merged.completed, threaded.completed);
    assert_eq!(merged.dropped, threaded.dropped);
    assert_eq!(merged.attempted, threaded.attempted);
    assert_eq!(
        merged.mean_latency.to_bits(),
        threaded.mean_latency.to_bits()
    );
    assert_eq!(merged.ci95.to_bits(), threaded.ci95.to_bits());
    assert_eq!(merged.rep_means.len(), threaded.rep_means.len());
    for (a, b) in merged.rep_means.iter().zip(&threaded.rep_means) {
        assert_eq!(a.to_bits(), b.to_bits(), "rep mean order");
    }
    for (i, (a, b)) in merged.per_model.iter().zip(&threaded.per_model).enumerate() {
        assert_stats_eq(a, b, &format!("merged per_model[{i}]"));
    }
    for (a, b) in merged.reps.iter().zip(&threaded.reps) {
        assert_result_eq(a, b, "replication");
    }
    assert!(threaded.ci95 > 0.0, "4 distinct seeds must spread the means");
}
