//! Tenant-lifecycle tests over the synthetic manifest + emulated exec
//! backend: attach/detach semantics, admission control, stats keying
//! under churn, and concurrent submissions racing detaches. These run on
//! a fresh checkout (no artifacts, no XLA) — they exercise the same
//! coordinator code paths the PJRT deployment uses.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use swapless::analytic::{Config, TenantHandle};
use swapless::config::{HardwareSpec, RuntimeConfig};
use swapless::coordinator::{AttachError, AttachOptions, ConfigError, Server, ServerBuilder};
use swapless::model::Manifest;
use swapless::runtime::service::ExecBackend;
use swapless::sched::SloClass;
use swapless::tpu::CostModel;

fn builder() -> ServerBuilder {
    ServerBuilder::new(
        &Manifest::synthetic(),
        CostModel::new(HardwareSpec::default()),
    )
    .backend(ExecBackend::Emulated)
}

fn input_for(server: &Server, h: TenantHandle) -> Vec<f32> {
    let n: usize = server
        .model_meta(h)
        .expect("attached")
        .input_shape
        .iter()
        .product();
    vec![0.5; n]
}

#[test]
fn attach_infer_detach_round_trip() {
    let server = builder().adaptive(false).build().unwrap();
    assert!(server.handles().is_empty());

    let ha = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 2.0, ..Default::default() })
        .unwrap();
    let hb = server
        .attach("squeezenet", AttachOptions { rate_hint: 2.0, ..Default::default() })
        .unwrap();
    assert_ne!(ha, hb);
    assert_eq!(server.handles(), vec![ha, hb]);
    let cfg = server.current_config();
    assert_eq!(cfg.partitions.len(), 2);

    let a = server.infer(ha, input_for(&server, ha)).unwrap();
    assert_eq!(a.tenant, ha);
    assert!(a.latency_s > 0.0);
    let b = server.infer(hb, input_for(&server, hb)).unwrap();
    assert_eq!(b.tenant, hb);

    // Detach A: B is undisturbed, A's handle turns into clean errors.
    let input_a = input_for(&server, ha);
    let final_a = server.detach(ha).unwrap();
    assert!(final_a.detached);
    assert_eq!(final_a.latency.count(), 1);
    assert_eq!(server.handles(), vec![hb]);
    assert_eq!(server.current_config().partitions.len(), 1);
    assert!(server.infer(ha, input_a).is_err());
    assert!(server.detach(ha).is_err(), "double detach errors");
    server.infer(hb, input_for(&server, hb)).unwrap();

    let stats = server.stats();
    assert_eq!(stats.completed, 3);
    // Stats stay keyed by handle across the churn.
    assert_eq!(stats.tenant(ha).unwrap().latency.count(), 1);
    assert!(stats.tenant(ha).unwrap().detached);
    assert_eq!(stats.tenant(hb).unwrap().latency.count(), 2);
    assert!(!stats.tenant(hb).unwrap().detached);
    // Per-class accounting survives the detach too: every completion —
    // including the retired tenant's — landed in the default class.
    assert_eq!(stats.per_class.get(SloClass::Standard).count(), 3);
    assert_eq!(stats.per_class.total_count(), 3);
}

#[test]
fn attach_unknown_model_and_admission_rejection() {
    let server = builder().adaptive(false).build().unwrap();
    match server.attach("not-a-model", AttachOptions::default()) {
        Err(AttachError::UnknownModel(_)) => {}
        other => panic!("expected UnknownModel, got {other:?}"),
    }

    // A modest tenant is admitted...
    let h = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    // ...but a tenant declaring an impossible rate is refused with the
    // predicted objective, and the running tenant is undisturbed.
    match server.attach("inceptionv4", AttachOptions { rate_hint: 1e9, ..Default::default() }) {
        Err(AttachError::Admission(e)) => {
            assert!(
                e.predicted_objective.is_infinite(),
                "rejection must carry the diverged objective, got {}",
                e.predicted_objective
            );
            assert_eq!(e.n_tenants, 2);
        }
        other => panic!("expected Admission rejection, got {other:?}"),
    }
    assert_eq!(server.handles(), vec![h]);
    server.infer(h, input_for(&server, h)).unwrap();
}

#[test]
fn set_config_validates_and_counts_reconfigs() {
    let server = builder().adaptive(false).build().unwrap();
    let h = server
        .attach("efficientnet", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    let pp = server.model_meta(h).unwrap().partition_points;

    // Wrong dimensions: typed error, nothing installed.
    let err = server
        .set_config(Config {
            partitions: vec![0, 0],
            cores: vec![1, 1],
        })
        .unwrap_err();
    assert!(matches!(err, ConfigError::DimensionMismatch { tenants: 1, .. }));

    // Partition out of range.
    let err = server
        .set_config(Config {
            partitions: vec![pp + 1],
            cores: vec![0],
        })
        .unwrap_err();
    assert!(matches!(err, ConfigError::PartitionOutOfRange { .. }));

    // Core budget exceeded (k_max defaults to 4).
    let err = server
        .set_config(Config {
            partitions: vec![0],
            cores: vec![9],
        })
        .unwrap_err();
    assert!(matches!(err, ConfigError::CoreBudgetExceeded { .. }));

    // Valid installs count toward reconfigs; a no-op re-install does not.
    let before = server.stats().reconfigs;
    let cfg = Config {
        partitions: vec![1],
        cores: vec![2],
    };
    server.set_config(cfg.clone()).unwrap();
    assert_eq!(server.stats().reconfigs, before + 1);
    server.set_config(cfg).unwrap();
    assert_eq!(server.stats().reconfigs, before + 1, "no-op not counted");
    // The installed config serves correctly.
    server.infer(h, input_for(&server, h)).unwrap();
}

#[test]
fn split_equals_full_through_live_server() {
    // The emulated backend preserves the composition invariant through
    // the full coordinator path (TPU prefix -> CPU pool suffix).
    let server = builder().adaptive(false).build().unwrap();
    let h = server
        .attach("efficientnet", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    let pp = server.model_meta(h).unwrap().partition_points;
    server
        .set_config(Config {
            partitions: vec![pp],
            cores: vec![0],
        })
        .unwrap();
    let full = server.infer(h, input_for(&server, h)).unwrap().output;
    for p in 1..pp {
        server
            .set_config(Config {
                partitions: vec![p],
                cores: vec![2],
            })
            .unwrap();
        let split = server.infer(h, input_for(&server, h)).unwrap().output;
        assert_eq!(split, full, "split at p={p} diverged from full-TPU run");
    }
}

#[test]
fn concurrent_submissions_race_churn_cleanly() {
    // Submissions in flight during detach/attach cycles complete or fail
    // cleanly — never panic — and stats histograms stay keyed to the
    // right tenant. The adaptive policy runs at a short period so its
    // epoch-guarded installs race the churn too.
    let server = Arc::new(
        builder()
            .adaptive(true)
            .runtime(RuntimeConfig {
                rate_window_s: 1.0,
                realloc_period_s: 0.02,
                realloc_threshold: 0.05,
            })
            .build()
            .unwrap(),
    );
    let stable = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 4.0, ..Default::default() })
        .unwrap();
    let churned = Arc::new(Mutex::new(
        server
            .attach("squeezenet", AttachOptions { rate_hint: 4.0, ..Default::default() })
            .unwrap(),
    ));
    let stop = Arc::new(AtomicBool::new(false));

    let mut submitters = Vec::new();
    for worker in 0..4 {
        let server = server.clone();
        let churned = churned.clone();
        let stop = stop.clone();
        submitters.push(std::thread::spawn(move || {
            let mut ok = 0u64;
            let mut clean_errors = 0u64;
            let mut pending = Vec::new();
            while !stop.load(Ordering::SeqCst) {
                let h = if worker % 2 == 0 {
                    stable
                } else {
                    *churned.lock().unwrap()
                };
                // Input sized for either model (synthetic models share the
                // input shape); a detached handle must error, not panic.
                pending.push(server.submit(h, vec![0.5; 512]));
                if pending.len() >= 8 {
                    for rx in pending.drain(..) {
                        match rx.recv() {
                            Ok(Ok(_)) => ok += 1,
                            Ok(Err(_)) => clean_errors += 1,
                            Err(_) => clean_errors += 1,
                        }
                    }
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            for rx in pending {
                match rx.recv() {
                    Ok(Ok(_)) => ok += 1,
                    _ => clean_errors += 1,
                }
            }
            (ok, clean_errors)
        }));
    }

    // Churn loop: detach and re-attach the second tenant repeatedly while
    // the submitters hammer both handles.
    let mut cycles = 0;
    for _ in 0..6 {
        std::thread::sleep(Duration::from_millis(30));
        let old = *churned.lock().unwrap();
        if server.detach(old).is_ok() {
            cycles += 1;
        }
        std::thread::sleep(Duration::from_millis(10));
        let new = server
            .attach("squeezenet", AttachOptions { rate_hint: 4.0, ..Default::default() })
            .expect("re-attach after detach");
        *churned.lock().unwrap() = new;
    }
    std::thread::sleep(Duration::from_millis(30));
    stop.store(true, Ordering::SeqCst);

    let mut total_ok = 0u64;
    let mut total_clean = 0u64;
    for s in submitters {
        let (ok, clean) = s.join().expect("submitter panicked");
        total_ok += ok;
        total_clean += clean;
    }
    assert!(cycles >= 5, "churn loop barely ran ({cycles} cycles)");
    assert!(total_ok > 0, "no request completed");

    let stats = server.stats();
    // Every successful completion was recorded against some tenant, and
    // the per-tenant histograms sum to the completion counter.
    assert_eq!(stats.completed, total_ok);
    let hist_sum: u64 = stats.per_tenant.iter().map(|t| t.latency.count()).sum();
    assert_eq!(hist_sum, stats.completed);
    // The stable tenant's histogram lives on its original handle.
    let stable_stats = stats.tenant(stable).expect("stable tenant present");
    assert!(!stable_stats.detached);
    assert!(stable_stats.latency.count() > 0);
    // All churn generations are individually retired and keyed.
    let retired: Vec<_> = stats.per_tenant.iter().filter(|t| t.detached).collect();
    assert_eq!(retired.len(), cycles as usize);
    // Some submissions raced a detach and were refused cleanly (counted
    // either by the submitters or by the server's failed counter).
    let _ = total_clean;
}

/// A deterministic policy for plumbing tests: every period it toggles the
/// single tenant between 1 and 2 cores, so each `decide` yields a fresh
/// config and the coordinator must install + count it.
struct FlipPolicy {
    flip: bool,
}

impl swapless::sim::reconfig::ReconfigPolicy for FlipPolicy {
    fn period(&self) -> Option<f64> {
        Some(0.01)
    }

    fn observe_arrival(&mut self, _t: f64, _model: usize) {}

    fn decide(
        &mut self,
        _t: f64,
        tenants: &[swapless::analytic::Tenant],
        current: &Config,
    ) -> Option<Config> {
        if tenants.is_empty() {
            return None;
        }
        self.flip = !self.flip;
        let mut cfg = current.clone();
        cfg.partitions[0] = 0;
        cfg.cores[0] = if self.flip { 1 } else { 2 };
        if &cfg == current {
            None
        } else {
            Some(cfg)
        }
    }
}

#[test]
fn policy_thread_drives_reconfigurations() {
    // The live coordinator is driven by the same ReconfigPolicy trait as
    // the DES: a custom policy's periodic decisions are installed, served
    // under, and counted.
    let server = builder()
        .policy(Box::new(FlipPolicy { flip: false }))
        .build()
        .unwrap();
    let h = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    let input = input_for(&server, h);
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while server.stats().reconfigs < 4 && std::time::Instant::now() < deadline {
        server.infer(h, input.clone()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = server.stats();
    assert!(
        stats.reconfigs >= 4,
        "policy decisions were not installed (reconfigs={})",
        stats.reconfigs
    );
    assert!(!stats.decision_micros.is_empty());
    // Serving continued across every reconfiguration.
    assert!(stats.completed > 0);
    let cfg = server.current_config();
    assert_eq!(cfg.partitions, vec![0]);
    assert!(cfg.cores[0] == 1 || cfg.cores[0] == 2);
}
