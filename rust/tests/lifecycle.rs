//! Tenant-lifecycle tests over the synthetic manifest + emulated exec
//! backend: attach/detach semantics, admission control, stats keying
//! under churn, and concurrent submissions racing detaches. These run on
//! a fresh checkout (no artifacts, no XLA) — they exercise the same
//! coordinator code paths the PJRT deployment uses.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use swapless::analytic::{Config, TenantHandle};
use swapless::config::{HardwareSpec, RuntimeConfig};
use swapless::coordinator::{
    AttachError, AttachOptions, ConfigError, Request, RequestError, Server, ServerBuilder,
};
use swapless::model::Manifest;
use swapless::runtime::service::ExecBackend;
use swapless::sched::{OverloadPolicy, SloClass};
use swapless::tpu::CostModel;

fn builder() -> ServerBuilder {
    ServerBuilder::new(
        &Manifest::synthetic(),
        CostModel::new(HardwareSpec::default()),
    )
    .backend(ExecBackend::Emulated)
}

fn input_for(server: &Server, h: TenantHandle) -> Vec<f32> {
    let n: usize = server
        .model_meta(h)
        .expect("attached")
        .input_shape
        .iter()
        .product();
    vec![0.5; n]
}

#[test]
fn attach_infer_detach_round_trip() {
    let server = builder().adaptive(false).build().unwrap();
    assert!(server.handles().is_empty());

    let ha = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 2.0, ..Default::default() })
        .unwrap();
    let hb = server
        .attach("squeezenet", AttachOptions { rate_hint: 2.0, ..Default::default() })
        .unwrap();
    assert_ne!(ha, hb);
    assert_eq!(server.handles(), vec![ha, hb]);
    let cfg = server.current_config();
    assert_eq!(cfg.partitions.len(), 2);

    let a = server.submit(ha, input_for(&server, ha)).wait().unwrap();
    assert_eq!(a.tenant, ha);
    assert!(a.latency_s > 0.0);
    let b = server.submit(hb, input_for(&server, hb)).wait().unwrap();
    assert_eq!(b.tenant, hb);

    // Detach A: B is undisturbed, A's handle turns into clean errors.
    let input_a = input_for(&server, ha);
    let final_a = server.detach(ha).unwrap();
    assert!(final_a.detached);
    assert_eq!(final_a.latency.count(), 1);
    assert_eq!(server.handles(), vec![hb]);
    assert_eq!(server.current_config().partitions.len(), 1);
    assert!(server.submit(ha, input_a).wait().is_err());
    assert!(server.detach(ha).is_err(), "double detach errors");
    server.submit(hb, input_for(&server, hb)).wait().unwrap();

    let stats = server.stats();
    assert_eq!(stats.completed, 3);
    // Stats stay keyed by handle across the churn.
    assert_eq!(stats.tenant(ha).unwrap().latency.count(), 1);
    assert!(stats.tenant(ha).unwrap().detached);
    assert_eq!(stats.tenant(hb).unwrap().latency.count(), 2);
    assert!(!stats.tenant(hb).unwrap().detached);
    // Per-class accounting survives the detach too: every completion —
    // including the retired tenant's — landed in the default class.
    assert_eq!(stats.per_class.get(SloClass::Standard).count(), 3);
    assert_eq!(stats.per_class.total_count(), 3);
}

#[test]
fn attach_unknown_model_and_admission_rejection() {
    let server = builder().adaptive(false).build().unwrap();
    match server.attach("not-a-model", AttachOptions::default()) {
        Err(AttachError::UnknownModel(_)) => {}
        other => panic!("expected UnknownModel, got {other:?}"),
    }

    // A modest tenant is admitted...
    let h = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    // ...but a tenant declaring an impossible rate is refused with the
    // predicted objective, and the running tenant is undisturbed.
    match server.attach("inceptionv4", AttachOptions { rate_hint: 1e9, ..Default::default() }) {
        Err(AttachError::Admission(e)) => {
            assert!(
                e.predicted_objective.is_infinite(),
                "rejection must carry the diverged objective, got {}",
                e.predicted_objective
            );
            assert_eq!(e.n_tenants, 2);
        }
        other => panic!("expected Admission rejection, got {other:?}"),
    }
    assert_eq!(server.handles(), vec![h]);
    server.submit(h, input_for(&server, h)).wait().unwrap();
}

#[test]
fn set_config_validates_and_counts_reconfigs() {
    let server = builder().adaptive(false).build().unwrap();
    let h = server
        .attach("efficientnet", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    let pp = server.model_meta(h).unwrap().partition_points;

    // Wrong dimensions: typed error, nothing installed.
    let err = server
        .set_config(Config {
            partitions: vec![0, 0],
            cores: vec![1, 1],
        })
        .unwrap_err();
    assert!(matches!(err, ConfigError::DimensionMismatch { tenants: 1, .. }));

    // Partition out of range.
    let err = server
        .set_config(Config {
            partitions: vec![pp + 1],
            cores: vec![0],
        })
        .unwrap_err();
    assert!(matches!(err, ConfigError::PartitionOutOfRange { .. }));

    // Core budget exceeded (k_max defaults to 4).
    let err = server
        .set_config(Config {
            partitions: vec![0],
            cores: vec![9],
        })
        .unwrap_err();
    assert!(matches!(err, ConfigError::CoreBudgetExceeded { .. }));

    // Valid installs count toward reconfigs; a no-op re-install does not.
    let before = server.stats().reconfigs;
    let cfg = Config {
        partitions: vec![1],
        cores: vec![2],
    };
    server.set_config(cfg.clone()).unwrap();
    assert_eq!(server.stats().reconfigs, before + 1);
    server.set_config(cfg).unwrap();
    assert_eq!(server.stats().reconfigs, before + 1, "no-op not counted");
    // The installed config serves correctly.
    server.submit(h, input_for(&server, h)).wait().unwrap();
}

#[test]
fn split_equals_full_through_live_server() {
    // The emulated backend preserves the composition invariant through
    // the full coordinator path (TPU prefix -> CPU pool suffix).
    let server = builder().adaptive(false).build().unwrap();
    let h = server
        .attach("efficientnet", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    let pp = server.model_meta(h).unwrap().partition_points;
    server
        .set_config(Config {
            partitions: vec![pp],
            cores: vec![0],
        })
        .unwrap();
    let full = server.submit(h, input_for(&server, h)).wait().unwrap().output;
    for p in 1..pp {
        server
            .set_config(Config {
                partitions: vec![p],
                cores: vec![2],
            })
            .unwrap();
        let split = server.submit(h, input_for(&server, h)).wait().unwrap().output;
        assert_eq!(split, full, "split at p={p} diverged from full-TPU run");
    }
}

#[test]
fn concurrent_submissions_race_churn_cleanly() {
    // Submissions in flight during detach/attach cycles complete or fail
    // cleanly — never panic — and stats histograms stay keyed to the
    // right tenant. The adaptive policy runs at a short period so its
    // epoch-guarded installs race the churn too.
    let server = Arc::new(
        builder()
            .adaptive(true)
            .runtime(RuntimeConfig {
                rate_window_s: 1.0,
                realloc_period_s: 0.02,
                realloc_threshold: 0.05,
            })
            .build()
            .unwrap(),
    );
    let stable = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 4.0, ..Default::default() })
        .unwrap();
    let churned = Arc::new(Mutex::new(
        server
            .attach("squeezenet", AttachOptions { rate_hint: 4.0, ..Default::default() })
            .unwrap(),
    ));
    let stop = Arc::new(AtomicBool::new(false));

    let mut submitters = Vec::new();
    for worker in 0..4 {
        let server = server.clone();
        let churned = churned.clone();
        let stop = stop.clone();
        submitters.push(std::thread::spawn(move || {
            let mut ok = 0u64;
            let mut clean_errors = 0u64;
            let mut pending = Vec::new();
            while !stop.load(Ordering::SeqCst) {
                let h = if worker % 2 == 0 {
                    stable
                } else {
                    *churned.lock().unwrap()
                };
                // Input sized for either model (synthetic models share the
                // input shape); a detached handle must error, not panic.
                pending.push(server.submit(h, vec![0.5; 512]));
                if pending.len() >= 8 {
                    for ticket in pending.drain(..) {
                        match ticket.wait() {
                            Ok(_) => ok += 1,
                            Err(_) => clean_errors += 1,
                        }
                    }
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            for ticket in pending {
                match ticket.wait() {
                    Ok(_) => ok += 1,
                    Err(_) => clean_errors += 1,
                }
            }
            (ok, clean_errors)
        }));
    }

    // Churn loop: detach and re-attach the second tenant repeatedly while
    // the submitters hammer both handles.
    let mut cycles = 0;
    for _ in 0..6 {
        std::thread::sleep(Duration::from_millis(30));
        let old = *churned.lock().unwrap();
        if server.detach(old).is_ok() {
            cycles += 1;
        }
        std::thread::sleep(Duration::from_millis(10));
        let new = server
            .attach("squeezenet", AttachOptions { rate_hint: 4.0, ..Default::default() })
            .expect("re-attach after detach");
        *churned.lock().unwrap() = new;
    }
    std::thread::sleep(Duration::from_millis(30));
    stop.store(true, Ordering::SeqCst);

    let mut total_ok = 0u64;
    let mut total_clean = 0u64;
    for s in submitters {
        let (ok, clean) = s.join().expect("submitter panicked");
        total_ok += ok;
        total_clean += clean;
    }
    assert!(cycles >= 5, "churn loop barely ran ({cycles} cycles)");
    assert!(total_ok > 0, "no request completed");

    let stats = server.stats();
    // Every successful completion was recorded against some tenant, and
    // the per-tenant histograms sum to the completion counter.
    assert_eq!(stats.completed, total_ok);
    let hist_sum: u64 = stats.per_tenant.iter().map(|t| t.latency.count()).sum();
    assert_eq!(hist_sum, stats.completed);
    // Request conservation: every submission resolved exactly once —
    // completed, failed (detach races), or dropped by the overload layer
    // (zero here: Block policy, no deadlines, no cancellations).
    assert_eq!(
        stats.completed + stats.failed + stats.dropped(),
        total_ok + total_clean,
        "tickets resolved ({}) != submissions accounted",
        total_ok + total_clean
    );
    // The stable tenant's histogram lives on its original handle.
    let stable_stats = stats.tenant(stable).expect("stable tenant present");
    assert!(!stable_stats.detached);
    assert!(stable_stats.latency.count() > 0);
    // All churn generations are individually retired and keyed.
    let retired: Vec<_> = stats.per_tenant.iter().filter(|t| t.detached).collect();
    assert_eq!(retired.len(), cycles as usize);
    // Some submissions raced a detach and were refused cleanly (counted
    // either by the submitters or by the server's failed counter).
    let _ = total_clean;
}

/// A deterministic policy for plumbing tests: every period it toggles the
/// single tenant between 1 and 2 cores, so each `decide` yields a fresh
/// config and the coordinator must install + count it.
struct FlipPolicy {
    flip: bool,
}

impl swapless::sim::reconfig::ReconfigPolicy for FlipPolicy {
    fn period(&self) -> Option<f64> {
        Some(0.01)
    }

    fn observe_arrival(&mut self, _t: f64, _model: usize) {}

    fn decide(
        &mut self,
        _t: f64,
        tenants: &[swapless::analytic::Tenant],
        current: &Config,
    ) -> Option<Config> {
        if tenants.is_empty() {
            return None;
        }
        self.flip = !self.flip;
        let mut cfg = current.clone();
        cfg.partitions[0] = 0;
        cfg.cores[0] = if self.flip { 1 } else { 2 };
        if &cfg == current {
            None
        } else {
            Some(cfg)
        }
    }
}

#[test]
fn policy_thread_drives_reconfigurations() {
    // The live coordinator is driven by the same ReconfigPolicy trait as
    // the DES: a custom policy's periodic decisions are installed, served
    // under, and counted.
    let server = builder()
        .policy(Box::new(FlipPolicy { flip: false }))
        .build()
        .unwrap();
    let h = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    let input = input_for(&server, h);
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while server.stats().reconfigs < 4 && std::time::Instant::now() < deadline {
        server.submit(h, input.clone()).wait().unwrap();
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = server.stats();
    assert!(
        stats.reconfigs >= 4,
        "policy decisions were not installed (reconfigs={})",
        stats.reconfigs
    );
    assert!(!stats.decision_micros.is_empty());
    // Serving continued across every reconfiguration.
    assert!(stats.completed > 0);
    let cfg = server.current_config();
    assert_eq!(cfg.partitions, vec![0]);
    assert!(cfg.cores[0] == 1 || cfg.cores[0] == 2);
}

#[test]
fn detach_resolves_every_cpu_pool_ticket() {
    // The detach path claims queued CPU-pool jobs "fail through their
    // completion callbacks" — pin it: pile work onto a tenant's CPU pool
    // (all-CPU config, single gated core), detach while most of it is
    // still queued, and assert EVERY in-flight ticket resolves — a
    // completion or a typed error, never a hang — and that the counters
    // conserve the submission count.
    let server = builder().adaptive(false).build().unwrap();
    let h = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 2.0, ..Default::default() })
        .unwrap();
    server
        .set_config(Config {
            partitions: vec![0],
            cores: vec![1],
        })
        .unwrap();
    const N: usize = 48;
    let input = input_for(&server, h);
    let mut pending = Vec::new();
    for _ in 0..N {
        pending.push(server.submit(h, input.clone()));
    }
    // Detach races the drain: some jobs executed, the rest are queued in
    // the CPU pool (never the TPU queue — partitions are 0).
    let final_stats = server.detach(h).unwrap();
    let mut completed = 0u64;
    let mut detached_errors = 0u64;
    let mut other_errors = 0u64;
    for mut ticket in pending {
        match ticket.wait_timeout(Duration::from_secs(10)) {
            None => panic!("ticket hung across the racing detach"),
            Some(Ok(_)) => completed += 1,
            Some(Err(RequestError::Detached(e))) => {
                assert_eq!(e, h);
                detached_errors += 1;
            }
            Some(Err(_)) => other_errors += 1,
        }
    }
    assert_eq!(completed + detached_errors + other_errors, N as u64);
    assert!(
        detached_errors > 0,
        "no job was still queued at detach — the race never happened \
         (completed {completed})"
    );
    // In-flight work that finished landed in the retired histogram; the
    // failures landed in the failed counter. Nothing is lost.
    let stats = server.stats();
    assert_eq!(stats.completed, completed);
    assert_eq!(final_stats.handle, h);
    assert_eq!(stats.failed, detached_errors + other_errors);
    assert_eq!(stats.completed + stats.failed + stats.dropped(), N as u64);
}

#[test]
fn bounded_admission_rejects_with_typed_backpressure() {
    // queue-cap 0 + Reject: every submission is refused synchronously
    // with the typed Overloaded payload (station, depth, capacity, wait
    // estimate) — and the counters attribute it per tenant and class.
    let server = builder()
        .adaptive(false)
        .queue_capacity(0)
        .overload(OverloadPolicy::Reject)
        .build()
        .unwrap();
    let h = server
        .attach(
            "mobilenetv2",
            AttachOptions {
                rate_hint: 1.0,
                class: SloClass::Interactive,
            },
        )
        .unwrap();
    match server.submit(h, input_for(&server, h)).wait() {
        Err(RequestError::Overloaded(o)) => {
            assert_eq!(o.capacity, 0);
            assert_eq!(o.queue_depth, 0);
            assert_eq!(o.estimated_wait_s, 0.0);
            assert!(o.station == "tpu" || o.station.starts_with("cpu"));
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    let stats = server.stats();
    assert_eq!(stats.accepted, 0);
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.per_class.rejected(SloClass::Interactive), 1);
    assert_eq!(stats.tenant(h).unwrap().rejected, 1);
    // Raising the cap un-wedges the same server.
    // (cap is fixed at build time; a fresh server with headroom serves.)
    let server2 = builder()
        .adaptive(false)
        .queue_capacity(64)
        .overload(OverloadPolicy::Reject)
        .build()
        .unwrap();
    let h2 = server2
        .attach("mobilenetv2", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    server2.submit(h2, input_for(&server2, h2)).wait().unwrap();
    assert_eq!(server2.stats().accepted, 1);
}

#[test]
fn cancel_resolves_queued_request_with_typed_error() {
    // A cancelled request that has not started executing resolves with
    // RequestError::Cancelled and counts as cancelled, not failed.
    let server = builder().adaptive(false).build().unwrap();
    let h = server
        .attach("inceptionv4", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    let input = input_for(&server, h);
    // Keep the single TPU worker busy with a burst, then cancel the tail
    // submissions while they queue behind it.
    let mut head = Vec::new();
    for _ in 0..4 {
        head.push(server.submit(h, input.clone()));
    }
    let tail = server.submit(h, input.clone());
    tail.cancel();
    assert!(tail.is_cancelled());
    let tail_result = tail.wait();
    for t in head {
        t.wait().unwrap();
    }
    match tail_result {
        // Overwhelmingly: cancelled while queued -> typed Cancelled.
        Err(RequestError::Cancelled) => {
            let stats = server.stats();
            assert_eq!(stats.cancelled, 1);
            assert_eq!(stats.failed, 0);
        }
        // The worker may already have started it — then it completes.
        Ok(_) => {}
        other => panic!("expected Cancelled or completion, got {other:?}"),
    }
}

#[test]
fn request_api_covers_retired_shim_semantics() {
    // The deprecated submit_with_class/infer shims are gone after their
    // one-PR deprecation cycle; this pins the Request/Ticket equivalents
    // of everything they guaranteed: per-request class override lands in
    // the overridden class, a blocking wait round-trips, and the real
    // typed failure (not a flattened "server dropped request") surfaces
    // after a detach.
    let server = builder().adaptive(false).build().unwrap();
    let h = server
        .attach("squeezenet", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    let done = server
        .submit(
            h,
            Request::new(input_for(&server, h)).with_class(SloClass::Batch),
        )
        .wait()
        .unwrap();
    assert_eq!(done.tenant, h);
    assert_eq!(server.stats().per_class.get(SloClass::Batch).count(), 1);
    let input = input_for(&server, h);
    server.submit(h, Request::new(input.clone())).wait().unwrap();
    server.detach(h).unwrap();
    match server.submit(h, Request::new(input)).wait() {
        Err(RequestError::NotAttached(handle)) => assert_eq!(handle, h),
        other => panic!("expected NotAttached, got {other:?}"),
    }
}

#[test]
fn deadline_drop_expires_hopeless_requests_live() {
    // Under DeadlineDrop, a request whose deadline already passed at
    // submission resolves immediately with DeadlineExceeded; a generous
    // deadline sails through. (The sim-vs-live drop parity test pins the
    // same rule against the DES.)
    let server = builder()
        .adaptive(false)
        .overload(OverloadPolicy::DeadlineDrop)
        .build()
        .unwrap();
    let h = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    let input = input_for(&server, h);
    match server
        .submit(h, Request::new(input.clone()).with_deadline(Duration::ZERO))
        .wait()
    {
        Err(RequestError::DeadlineExceeded { .. }) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    server
        .submit(h, Request::new(input).with_deadline(Duration::from_secs(30)))
        .wait()
        .unwrap();
    let stats = server.stats();
    assert_eq!(stats.expired, 1);
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.per_class.goodput(SloClass::Standard), 1);
}
