//! Integration tests over the real AOT artifacts: manifest → PJRT runtime
//! → serving coordinator → experiment harness. These need `make artifacts`
//! to have run; they skip (with a notice) otherwise so `cargo test` stays
//! green on a fresh checkout.

use swapless::alloc;
use swapless::analytic::{AnalyticModel, Config, Tenant};
use swapless::config::HardwareSpec;
use swapless::coordinator::{AttachOptions, ServerBuilder};
use swapless::experiments as exp;
use swapless::model::Manifest;
use swapless::runtime::service::{ExecBackend, ExecService};
use swapless::runtime::Engine;
use swapless::tpu::CostModel;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("artifacts/ not built; skipping integration test");
            None
        }
    }
}

#[test]
fn manifest_covers_table2() {
    let Some(m) = manifest() else { return };
    assert_eq!(m.models.len(), 9);
    let expected = [
        ("squeezenet", 2),
        ("mobilenetv2", 5),
        ("efficientnet", 6),
        ("mnasnet", 7),
        ("gpunet", 5),
        ("densenet201", 7),
        ("resnet50v2", 8),
        ("xception", 11),
        ("inceptionv4", 11),
    ];
    for (name, pp) in expected {
        let meta = m.get(name).unwrap();
        assert_eq!(meta.partition_points, pp, "{name}");
        for seg in &meta.segments {
            assert!(std::path::Path::new(&m.artifact_path(seg)).exists());
        }
    }
}

#[test]
fn engine_executes_and_composes_segments() {
    let Some(m) = manifest() else { return };
    let meta = m.get("squeezenet").unwrap().clone();
    let mut engine = Engine::new().unwrap();
    engine.load_model(&m, &meta).unwrap();

    let n_in: usize = meta.input_shape.iter().product();
    let input = vec![0.5f32; n_in];

    // Segment-by-segment equals execute_range.
    let mut x = input.clone();
    for i in 0..meta.partition_points {
        x = engine.execute_segment("squeezenet", i, &x).unwrap();
    }
    let direct = engine
        .execute_range("squeezenet", 0, meta.partition_points, &input)
        .unwrap();
    assert_eq!(x.len(), direct.len());
    for (a, b) in x.iter().zip(&direct) {
        assert!((a - b).abs() < 1e-5);
    }
    // Deterministic across invocations.
    let again = engine
        .execute_range("squeezenet", 0, meta.partition_points, &input)
        .unwrap();
    assert_eq!(direct, again);
    // Output is the class-logit vector.
    assert_eq!(direct.len(), 10);
}

#[test]
fn engine_rejects_bad_input_len() {
    let Some(m) = manifest() else { return };
    let meta = m.get("squeezenet").unwrap().clone();
    let mut engine = Engine::new().unwrap();
    engine.load_model(&m, &meta).unwrap();
    assert!(engine.execute_segment("squeezenet", 0, &[0.0; 3]).is_err());
    assert!(engine.execute_segment("nope", 0, &[0.0; 3]).is_err());
}

#[test]
fn exec_service_serves_from_other_threads() {
    let Some(m) = manifest() else { return };
    let svc = ExecService::start(&m, &["squeezenet".into()]).unwrap();
    let meta = m.get("squeezenet").unwrap().clone();
    let n_in: usize = meta.input_shape.iter().product();
    let mut joins = Vec::new();
    for _ in 0..4 {
        let h = svc.handle();
        let name = meta.name.clone();
        let pp = meta.partition_points;
        joins.push(std::thread::spawn(move || {
            h.execute_range(&name, 0, pp, vec![0.5; n_in]).unwrap().len()
        }));
    }
    for j in joins {
        assert_eq!(j.join().unwrap(), 10);
    }
}

#[test]
fn server_round_trip_split_execution() {
    let Some(m) = manifest() else { return };
    let cost = CostModel::new(HardwareSpec::default());
    let server = ServerBuilder::new(&m, cost)
        .adaptive(false)
        .backend(ExecBackend::Pjrt)
        .build()
        .unwrap();
    let h_sq = server
        .attach("squeezenet", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    let h_mb = server
        .attach("mobilenetv2", AttachOptions { rate_hint: 1.0, ..Default::default() })
        .unwrap();
    // Force split configs: prefix 1 segment, suffix on CPU pools.
    server
        .set_config(Config {
            partitions: vec![1, 2],
            cores: vec![2, 2],
        })
        .unwrap();
    for h in [h_sq, h_mb] {
        let n_in: usize = server.model_meta(h).unwrap().input_shape.iter().product();
        let done = server.submit(h, vec![0.5; n_in]).wait().unwrap();
        assert_eq!(done.output.len(), 10, "{h}");
        assert!(done.latency_s > 0.0);
    }
    let stats = server.stats();
    assert_eq!(stats.completed, 2);

    // Split output must equal the full-TPU output (numerics invariant).
    let n_in: usize = server.model_meta(h_sq).unwrap().input_shape.iter().product();
    let split_out = server.submit(h_sq, vec![0.25; n_in]).wait().unwrap().output;
    server
        .set_config(Config {
            partitions: vec![2, 5],
            cores: vec![0, 0],
        })
        .unwrap();
    let full_out = server.submit(h_sq, vec![0.25; n_in]).wait().unwrap().output;
    assert_eq!(split_out.len(), full_out.len());
    for (a, b) in split_out.iter().zip(&full_out) {
        assert!((a - b).abs() < 1e-4, "split vs full mismatch: {a} vs {b}");
    }
}

#[test]
fn experiments_run_on_real_manifest() {
    let Some(m) = manifest() else { return };
    let mut ctx = exp::Ctx::new(m, HardwareSpec::default());
    ctx.horizon = 200.0;

    let t2 = exp::table2::run(&ctx);
    assert_eq!(t2.rows.len(), 9);

    let f1 = exp::fig1::run(&ctx).unwrap();
    for row in &f1.rows {
        assert!(row.swap_fraction > 0.0 && row.swap_fraction < 1.0);
        assert!(row.observed_mean_ms > 0.0);
    }

    let f3 = exp::fig3::run(&ctx, "inceptionv4").unwrap();
    assert_eq!(f3.rows.len(), 11);
    let first = f3.rows[0].speedup;
    let last = f3.rows.last().unwrap().speedup;
    assert!(first > 2.0 * last, "Fig. 3 shape lost: {first} vs {last}");
}

#[test]
fn fig5_mape_stays_small() {
    let Some(m) = manifest() else { return };
    let mut ctx = exp::Ctx::new(m, HardwareSpec::default());
    ctx.horizon = 1000.0;
    let f5 = exp::fig5::run(&ctx, "inceptionv4", 0.2, &[1.0, 4.0]).unwrap();
    assert!(
        f5.mape_pct < 8.0,
        "single-tenant validation degraded: MAPE {:.1}%",
        f5.mape_pct
    );
    assert!(f5.within10 > 0.9);
}

#[test]
fn fig7_swapless_wins_where_memory_pressured() {
    let Some(m) = manifest() else { return };
    let mut ctx = exp::Ctx::new(m, HardwareSpec::default());
    ctx.horizon = 600.0;
    let wl = exp::fig7::run_workload(&ctx, &["efficientnet", "gpunet"], 0.5).unwrap();
    let compiler = wl.cells.iter().find(|c| c.policy == "compiler").unwrap();
    let swapless = wl.cells.iter().find(|c| c.policy == "swapless").unwrap();
    assert!(
        swapless.observed_ms < compiler.observed_ms,
        "swapless {} !< compiler {}",
        swapless.observed_ms,
        compiler.observed_ms
    );
    // And when everything fits, policies tie (within noise).
    let wl = exp::fig7::run_workload(&ctx, &["mobilenetv2", "squeezenet"], 0.2).unwrap();
    let compiler = wl.cells.iter().find(|c| c.policy == "compiler").unwrap();
    let swapless = wl.cells.iter().find(|c| c.policy == "swapless").unwrap();
    let rel = (swapless.observed_ms - compiler.observed_ms).abs() / compiler.observed_ms;
    assert!(rel < 0.25, "fits-in-SRAM workload should tie: {rel}");
}

#[test]
fn plan_then_observe_agrees_for_real_models() {
    // Close the loop: allocator's predicted objective vs DES observation.
    let Some(m) = manifest() else { return };
    let mut ctx = exp::Ctx::new(m, HardwareSpec::default());
    ctx.horizon = 1200.0;
    let tenants: Vec<Tenant> = vec![
        Tenant {
            model: ctx.manifest.get("efficientnet").unwrap().clone(),
            rate: 2.0,
        },
        Tenant {
            model: ctx.manifest.get("gpunet").unwrap().clone(),
            rate: 1.0,
        },
    ];
    let am = AnalyticModel::new(ctx.cost.clone());
    let plan = alloc::hill_climb(&am, &tenants, 4);
    let predicted = am.mean_latency(&tenants, &plan.config);
    let observed = ctx.observe(&tenants, &plan.config).mean_latency;
    let err = (observed - predicted).abs() / observed;
    assert!(
        err < 0.15,
        "predicted {predicted} observed {observed} err {err}"
    );
}
