"""Model-zoo structural tests: shapes, composition, determinism, Table II."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import zoo

TOL = dict(rtol=5e-4, atol=5e-4)

SMALL = ["squeezenet", "mobilenetv2", "gpunet"]


def test_zoo_has_nine_models():
    assert len(zoo.model_names()) == 9
    assert set(zoo.model_names()) == set(zoo.TABLE_II)


@pytest.mark.parametrize("name", zoo.model_names())
def test_segment_count_matches_table2(name):
    segs = zoo.build(name)
    assert len(segs) == zoo.TABLE_II[name][2]


@pytest.mark.parametrize("name", zoo.model_names())
def test_model_builds_and_shapes_chain(name):
    m = M.build_model(name)
    assert m.input_shape == zoo.INPUT_SHAPE
    assert m.output_shape == (1, zoo.NUM_CLASSES)
    for a, b in zip(m.infos[:-1], m.infos[1:]):
        assert a.out_shape == b.in_shape
    for info in m.infos:
        assert info.flops > 0
        assert 0.0 < info.mxu_util <= 1.0


@pytest.mark.parametrize("name", SMALL)
def test_segment_composition_equals_full(name):
    """Running segments one-by-one must equal the full forward pass."""
    m = M.build_model(name)
    x = jax.random.normal(jax.random.PRNGKey(11), m.input_shape)
    full = m.apply_full(x, use_pallas=False)
    y = x
    for i in range(m.num_segments):
        y = m.apply_segment(i, y, use_pallas=False)
    np.testing.assert_allclose(y, full, **TOL)


@pytest.mark.parametrize("name", ["squeezenet", "mobilenetv2"])
def test_pallas_path_equals_ref_path(name):
    m = M.build_model(name)
    x = jax.random.normal(jax.random.PRNGKey(5), m.input_shape)
    np.testing.assert_allclose(
        m.apply_full(x, use_pallas=True), m.apply_full(x, use_pallas=False), **TOL
    )


def test_build_model_deterministic():
    a = M.build_model("squeezenet")
    b = M.build_model("squeezenet")
    x = jnp.full(a.input_shape, 0.3, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(a.apply_full(x, use_pallas=False)),
        np.asarray(b.apply_full(x, use_pallas=False)),
    )


def test_build_model_seed_changes_weights():
    a = M.build_model("squeezenet", seed=0)
    b = M.build_model("squeezenet", seed=1)
    x = jnp.full(a.input_shape, 0.3, jnp.float32)
    ya = np.asarray(a.apply_full(x, use_pallas=False))
    yb = np.asarray(b.apply_full(x, use_pallas=False))
    assert not np.allclose(ya, yb)


def test_manifest_entry_scaling():
    m = M.build_model("squeezenet")
    entry = M.scaled_manifest_entry(m)
    size_mb, flops_g, pp = zoo.TABLE_II["squeezenet"]
    assert entry["partition_points"] == pp
    assert len(entry["segments"]) == pp
    total_sim_bytes = sum(s["sim_weight_bytes"] for s in entry["segments"])
    total_sim_flops = sum(s["sim_flops"] for s in entry["segments"])
    assert abs(total_sim_bytes - size_mb * 1e6) / (size_mb * 1e6) < 0.01
    assert abs(total_sim_flops - flops_g * 1e9) / (flops_g * 1e9) < 0.01
    # within-model distribution follows real parameter distribution
    reals = [s["real_param_bytes"] for s in entry["segments"]]
    sims = [s["sim_weight_bytes"] for s in entry["segments"]]
    order_real = np.argsort(reals)
    order_sim = np.argsort(sims)
    np.testing.assert_array_equal(order_real, order_sim)


def test_manifest_io_bytes_are_int8_sized():
    m = M.build_model("mobilenetv2")
    entry = M.scaled_manifest_entry(m)
    s0 = entry["segments"][0]
    assert s0["in_bytes"] == int(np.prod(m.input_shape))
    assert s0["out_bytes"] == int(np.prod(m.infos[0].out_shape))


def test_late_segments_have_lower_mxu_util():
    """The Fig. 3 opportunity: utilization decays towards the tail."""
    m = M.build_model("inceptionv4")
    utils = [s.mxu_util for s in m.infos]
    assert utils[-1] < utils[0]
