"""Hypothesis sweeps of the Pallas matmul kernel vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mm
from compile.kernels import ref

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    np.testing.assert_allclose(mm.matmul(x, w), ref.matmul(x, w), **TOL)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    act=st.sampled_from(["none", "relu", "relu6", "sigmoid"]),
    seed=st.integers(0, 2**16),
)
def test_matmul_bias_act_matches_ref(m, k, n, act, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    np.testing.assert_allclose(
        mm.matmul(x, w, b, act=act), ref.matmul(x, w, b, act=act), **TOL
    )


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 32, 8), (128, 128, 128)])
def test_matmul_block_shape_invariance(blocks):
    """Result must not depend on the tiling choice."""
    bm, bn, bk = blocks
    x = _rand(3, (50, 33))
    w = _rand(4, (33, 27))
    out = mm.matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(out, ref.matmul(x, w), **TOL)


def test_matmul_multi_k_accumulation():
    """K larger than block_k exercises the grid accumulation path."""
    x = _rand(5, (17, 300))
    w = _rand(6, (300, 19))
    out = mm.matmul(x, w, block_k=64)
    np.testing.assert_allclose(out, ref.matmul(x, w), **TOL)


def test_matmul_rejects_bad_shapes():
    x = _rand(0, (4, 5))
    w = _rand(1, (6, 3))
    with pytest.raises(ValueError):
        mm.matmul(x, w)
    with pytest.raises(ValueError):
        mm.matmul(x, _rand(1, (5, 3)), act="swish")


def test_vmem_budget_default_blocks():
    """Default MXU tiles must fit the Edge-TPU-analogue 8 MB scratchpad."""
    assert mm.vmem_bytes() < 8 * 1024 * 1024


def test_mxu_utilization_monotone():
    """Bigger tiles fill the systolic array more."""
    small = mm.mxu_utilization(1, 10, 64)
    big = mm.mxu_utilization(1024, 128, 256)
    assert 0.0 < small < big <= 1.0
