"""AOT pipeline tests: HLO text properties the rust loader depends on."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile import zoo


@pytest.fixture(scope="module")
def squeezenet():
    return M.build_model("squeezenet")


def test_hlo_text_no_elided_constants(squeezenet):
    """'{...}' elision silently zeroes the weights in XLA 0.5.1's parser."""
    text = aot.lower_segment(squeezenet, 0)
    assert "{...}" not in text
    assert text.startswith("HloModule")


def test_hlo_text_no_new_metadata_attrs(squeezenet):
    """jax>=0.5 metadata attrs crash the 0.5.1 text parser."""
    text = aot.lower_segment(squeezenet, 0)
    assert "source_end_line" not in text
    assert "metadata=" not in text


def test_hlo_entry_layout_matches_manifest(squeezenet):
    text = aot.lower_segment(squeezenet, 0)
    in_shape = squeezenet.infos[0].in_shape
    out_shape = squeezenet.infos[0].out_shape
    dims_in = ",".join(str(d) for d in in_shape)
    dims_out = ",".join(str(d) for d in out_shape)
    assert f"f32[{dims_in}]" in text.splitlines()[0]
    assert f"f32[{dims_out}]" in text.splitlines()[0]


def test_hlo_output_is_tuple(squeezenet):
    """return_tuple=True — the rust side unwraps with to_tuple1."""
    text = aot.lower_segment(squeezenet, 0)
    first = text.splitlines()[0]
    assert ")->(" in first.replace(" ", "")


def test_ref_and_pallas_lower_to_same_signature(squeezenet):
    a = aot.lower_segment(squeezenet, 0, use_pallas=True).splitlines()[0]
    b = aot.lower_segment(squeezenet, 0, use_pallas=False).splitlines()[0]
    assert a.split("entry_computation_layout")[1] == b.split("entry_computation_layout")[1]


def test_compile_model_writes_artifacts(tmp_path):
    entry = aot.compile_model("squeezenet", str(tmp_path), quiet=True)
    assert entry["name"] == "squeezenet"
    for seg in entry["segments"]:
        assert os.path.exists(os.path.join(tmp_path, seg["artifact"]))


def test_main_single_model(tmp_path):
    rc = aot.main(["--out", str(tmp_path), "--models", "squeezenet", "--quiet"])
    assert rc == 0
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert manifest["kernel_path"] == "pallas"
    assert len(manifest["models"]) == 1
    assert manifest["models"][0]["partition_points"] == zoo.TABLE_II["squeezenet"][2]


def test_repo_manifest_if_built():
    """If `make artifacts` has run, sanity-check the committed manifest."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built yet")
    manifest = json.load(open(path))
    names = {m["name"] for m in manifest["models"]}
    assert names == set(zoo.model_names())
    for m in manifest["models"]:
        assert len(m["segments"]) == zoo.TABLE_II[m["name"]][2]
        for seg in m["segments"]:
            apath = os.path.join(os.path.dirname(path), seg["artifact"])
            assert os.path.exists(apath), f"missing artifact {seg['artifact']}"
