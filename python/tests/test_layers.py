"""Layer-framework tests: shapes, FLOPs/param accounting, composites."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L


def _init(layer, in_shape, seed=0):
    return layer.init(jax.random.PRNGKey(seed), in_shape)


def test_conv_shape_same_and_valid():
    _, s = _init(L.Conv(3, 3, 8, stride=2, padding="SAME"), (1, 9, 9, 3))
    assert s == (1, 5, 5, 8)
    _, s = _init(L.Conv(3, 3, 8, stride=1, padding="VALID"), (1, 9, 9, 3))
    assert s == (1, 7, 7, 8)


def test_conv_flops_and_params():
    layer = L.Conv(3, 3, 8, stride=1, padding="SAME")
    in_shape = (1, 4, 4, 2)
    assert layer.flops(in_shape) == 2 * 16 * 9 * 2 * 8
    assert layer.param_count(in_shape) == 9 * 2 * 8 + 8


def test_dwconv_shape_and_params():
    layer = L.DWConv(3, 3, stride=2)
    _, s = _init(layer, (1, 8, 8, 6))
    assert s == (1, 4, 4, 6)
    assert layer.param_count((1, 8, 8, 6)) == 9 * 6 + 6


def test_pool_and_gap_shapes():
    _, s = _init(L.Pool("max", 2, 2), (1, 8, 8, 4))
    assert s == (1, 4, 4, 4)
    _, s = _init(L.GlobalAvgPool(), (1, 8, 8, 4))
    assert s == (1, 4)


def test_dense_after_gap():
    gap = L.GlobalAvgPool()
    dense = L.Dense(10)
    p1, s1 = _init(gap, (1, 8, 8, 4))
    p2, s2 = _init(dense, s1)
    assert s2 == (1, 10)
    x = jnp.ones((1, 8, 8, 4))
    y = dense.apply(p2, gap.apply(p1, x, False), use_pallas=False)
    assert y.shape == (1, 10)


def test_residual_requires_shape_preservation():
    good = L.Residual([L.Conv(3, 3, 4, act="none")])
    _init(good, (1, 8, 8, 4))  # ok
    bad = L.Residual([L.Conv(3, 3, 5, act="none")])
    with pytest.raises(ValueError):
        _init(bad, (1, 8, 8, 4))


def test_residual_is_identity_plus_inner():
    layer = L.Residual([L.Conv(1, 1, 3, act="none")])
    params, _ = _init(layer, (1, 4, 4, 3))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 4, 3))
    inner = L.apply_sequence(layer.inner, params["inner"], x, False)
    np.testing.assert_allclose(
        layer.apply(params, x, False), x + inner, rtol=1e-6
    )


def test_branch_concat_channels_add_up():
    layer = L.Branch([[L.Conv(1, 1, 3)], [L.Conv(3, 3, 5)]], combine="concat")
    _, s = _init(layer, (1, 6, 6, 2))
    assert s == (1, 6, 6, 8)


def test_branch_add_requires_same_shape():
    bad = L.Branch([[L.Conv(1, 1, 3)], [L.Conv(1, 1, 4)]], combine="add")
    with pytest.raises(ValueError):
        _init(bad, (1, 6, 6, 2))


def test_branch_empty_branch_is_identity():
    """DenseNet-style concat(x, f(x)) uses an empty branch as identity."""
    layer = L.Branch([[], [L.Conv(1, 1, 4)]], combine="concat")
    params, s = _init(layer, (1, 5, 5, 3))
    assert s == (1, 5, 5, 7)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 5, 5, 3))
    y = layer.apply(params, x, False)
    np.testing.assert_allclose(y[..., :3], x, rtol=1e-6)


def test_sequence_flops_additive():
    seq = [L.Conv(3, 3, 4), L.Pool("avg", 2, 2), L.Conv(1, 1, 8)]
    in_shape = (1, 8, 8, 2)
    total = L.flops_sequence(seq, in_shape)
    s0 = seq[0].flops(in_shape)
    _, sh1 = _init(seq[0], in_shape)
    s1 = seq[1].flops(sh1)
    _, sh2 = _init(seq[1], sh1)
    s2 = seq[2].flops(sh2)
    assert total == s0 + s1 + s2


def test_util_sequence_is_flop_weighted():
    heavy = L.Conv(3, 3, 64)  # high util, most flops
    light = L.Dense(4)
    seq = [heavy, L.GlobalAvgPool(), light]
    in_shape = (1, 16, 16, 8)
    u = L.util_sequence(seq, in_shape)
    assert heavy.mxu_util(in_shape) >= u  # pulled down by the tail
    assert u > 0


def test_mxu_util_bounds_all_layers():
    layers = [
        L.Conv(3, 3, 8),
        L.DWConv(3, 3),
        L.Pool("max", 2, 2),
        L.GlobalAvgPool(),
    ]
    for layer in layers:
        u = layer.mxu_util((1, 16, 16, 8))
        assert 0.0 < u <= 1.0, layer
