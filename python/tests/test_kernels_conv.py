"""Hypothesis sweeps of the conv2d and depthwise Pallas kernels vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as cv
from compile.kernels import depthwise as dw
from compile.kernels import ref

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(4, 20),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_matches_ref(h, cin, cout, k, stride, padding, seed):
    if padding == "VALID" and h < k:
        return
    x = _rand(seed, (1, h, h, cin))
    w = _rand(seed + 1, (k, k, cin, cout))
    b = _rand(seed + 2, (cout,))
    got = cv.conv2d(x, w, b, stride=stride, padding=padding, act="relu")
    want = ref.conv2d(x, w, b, stride=stride, padding=padding, act="relu")
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(4, 20),
    c=st.integers(1, 16),
    k=st.sampled_from([3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**16),
)
def test_depthwise_matches_ref(h, c, k, stride, padding, seed):
    if padding == "VALID" and h < k:
        return
    x = _rand(seed, (1, h, h, c))
    w = _rand(seed + 1, (k, k, c))
    b = _rand(seed + 2, (c,))
    got = dw.depthwise_conv2d(x, w, b, stride=stride, padding=padding, act="relu6")
    want = ref.depthwise_conv2d(x, w, b, stride=stride, padding=padding, act="relu6")
    np.testing.assert_allclose(got, want, **TOL)


def test_conv2d_batch():
    """N>1 exercises the batched im2col path."""
    x = _rand(0, (3, 9, 9, 4))
    w = _rand(1, (3, 3, 4, 6))
    np.testing.assert_allclose(cv.conv2d(x, w), ref.conv2d(x, w), **TOL)


def test_depthwise_batch():
    x = _rand(0, (3, 9, 9, 4))
    w = _rand(1, (3, 3, 4))
    np.testing.assert_allclose(
        dw.depthwise_conv2d(x, w), ref.depthwise_conv2d(x, w), **TOL
    )


def test_depthwise_channel_blocking():
    """C larger than block_c exercises the channel-grid path."""
    x = _rand(2, (1, 7, 7, 300))
    w = _rand(3, (3, 3, 300))
    got = dw.depthwise_conv2d(x, w, block_c=128)
    np.testing.assert_allclose(got, ref.depthwise_conv2d(x, w), **TOL)


def test_conv2d_channel_mismatch_raises():
    with pytest.raises(ValueError):
        cv.conv2d(_rand(0, (1, 8, 8, 3)), _rand(1, (3, 3, 4, 8)))
    with pytest.raises(ValueError):
        dw.depthwise_conv2d(_rand(0, (1, 8, 8, 3)), _rand(1, (3, 3, 4)))


def test_im2col_dims():
    x = _rand(0, (2, 10, 10, 3))
    cols = cv.im2col(x, 3, 3, 2, "SAME")
    assert cols.shape == (2 * 5 * 5, 3 * 3 * 3)


def test_conv_vmem_check():
    """Every zoo-scale conv stays under the 8 MB budget."""
    assert cv.check_vmem((1, 64, 64, 3), 3, 3, 32, 2, "SAME") < cv.VMEM_BUDGET_BYTES


def test_conv_mxu_util_spatial_decay():
    """Late (small-spatial) layers underfill the MXU — the Fig. 3 driver."""
    early = cv.mxu_utilization((1, 64, 64, 16), 3, 3, 32, 1, "SAME")
    late = cv.mxu_utilization((1, 4, 4, 128), 3, 3, 128, 1, "SAME")
    assert early > 0 and late > 0
    # early layers have far more output rows (M), hence >= utilization
    assert early >= late
