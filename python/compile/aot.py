"""AOT compile path: lower every model segment to an HLO-text artifact.

Run once via ``make artifacts``; the rust binary is self-contained after.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md).

Outputs:
  artifacts/<model>/seg<j>.hlo.txt   one per segment
  artifacts/manifest.json            zoo metadata the rust side consumes
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model as M
from . import zoo


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path).

    Two printer details matter for the rust loader:
      * ``print_large_constants=True`` — the default printer elides big
        literals as ``{...}``, which the XLA 0.5.1 text parser silently
        reads back as *zeros* (all model weights would vanish);
      * ``print_metadata=False`` — jax ≥0.5 emits ``source_end_line``-style
        metadata attributes the 0.5.1 parser rejects outright.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    mod = xc._xla.HloModule.from_serialized_hlo_module_proto(
        comp.as_serialized_hlo_module_proto()
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return mod.to_string(opts)


def lower_segment(mdl: M.ModelDef, i: int, use_pallas: bool = True) -> str:
    fn = M.segment_fn(mdl, i, use_pallas=use_pallas)
    spec = jax.ShapeDtypeStruct(mdl.infos[i].in_shape, jax.numpy.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def compile_model(name: str, out_dir: str, use_pallas: bool = True, quiet: bool = False) -> dict:
    mdl = M.build_model(name)
    model_dir = os.path.join(out_dir, name)
    os.makedirs(model_dir, exist_ok=True)
    for i in range(mdl.num_segments):
        t0 = time.time()
        text = lower_segment(mdl, i, use_pallas=use_pallas)
        path = os.path.join(model_dir, f"seg{i}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        if not quiet:
            print(
                f"  {name}/seg{i}: {len(text)} chars, "
                f"in={mdl.infos[i].in_shape} out={mdl.infos[i].out_shape} "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    return M.scaled_manifest_entry(mdl)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="SwapLess AOT artifact builder")
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument("--models", default="all", help="comma list or 'all'")
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the pure-jnp reference path instead of the Pallas kernels",
    )
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    names = zoo.model_names() if args.models == "all" else args.models.split(",")
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "version": 1,
        "input_dtype": "f32",
        "kernel_path": "ref" if args.no_pallas else "pallas",
        "models": [],
    }
    t0 = time.time()
    for name in names:
        print(f"[aot] {name}", flush=True)
        manifest["models"].append(
            compile_model(name, args.out, use_pallas=not args.no_pallas, quiet=args.quiet)
        )
    path = os.path.join(args.out, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {path} ({time.time() - t0:.1f}s total)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
