"""SwapLess build-time Python: Pallas kernels (L1), JAX model zoo (L2), AOT.

Nothing in this package is imported at serve time — ``aot.py`` lowers every
model segment to an HLO-text artifact once, and the rust coordinator (L3)
loads and executes the artifacts through PJRT.
"""
