"""Layer-2 building blocks for the model zoo.

A tiny functional layer framework: every layer knows how to
  * initialize its parameters (deterministic, seeded),
  * apply itself through the Pallas kernels (or the jnp reference path),
  * report FLOPs, parameter count, and MXU utilization for the manifest.

Shape convention: NHWC activations; after :class:`GlobalAvgPool` the
activation is (N, C) and only :class:`Dense` layers may follow.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import conv2d as cv
from .kernels import depthwise as dw
from .kernels import matmul as mm
from .kernels import ref
from .kernels.depthwise import VPU_FALLBACK_UTILIZATION

Shape = Tuple[int, ...]


def _out_hw(h: int, w: int, k: int, stride: int, padding: str) -> Tuple[int, int]:
    if padding == "SAME":
        return -(-h // stride), -(-w // stride)
    return (h - k) // stride + 1, (w - k) // stride + 1


@dataclass
class Conv:
    """Standard NHWC convolution with fused bias + activation."""

    kh: int
    kw: int
    cout: int
    stride: int = 1
    padding: str = "SAME"
    act: str = "relu"

    def init(self, key, in_shape: Shape):
        n, h, w, cin = in_shape
        kw_, kb = jax.random.split(key)
        fan_in = self.kh * self.kw * cin
        weight = jax.random.normal(kw_, (self.kh, self.kw, cin, self.cout), jnp.float32)
        weight = weight * (2.0 / fan_in) ** 0.5
        bias = 0.01 * jax.random.normal(kb, (self.cout,), jnp.float32)
        ho, wo = _out_hw(h, w, self.kh, self.stride, self.padding)
        return {"w": weight, "b": bias}, (n, ho, wo, self.cout)

    def apply(self, params, x, use_pallas: bool = True):
        fn = cv.conv2d if use_pallas else ref.conv2d
        return fn(
            x, params["w"], params["b"],
            stride=self.stride, padding=self.padding, act=self.act,
        )

    def flops(self, in_shape: Shape) -> int:
        n, h, w, cin = in_shape
        ho, wo = _out_hw(h, w, self.kh, self.stride, self.padding)
        return 2 * n * ho * wo * self.kh * self.kw * cin * self.cout

    def param_count(self, in_shape: Shape) -> int:
        cin = in_shape[-1]
        return self.kh * self.kw * cin * self.cout + self.cout

    def mxu_util(self, in_shape: Shape) -> float:
        return cv.mxu_utilization(
            in_shape, self.kh, self.kw, self.cout, self.stride, self.padding
        )


@dataclass
class DWConv:
    """Depthwise convolution — VPU path on the Edge TPU (no MXU reuse)."""

    kh: int
    kw: int
    stride: int = 1
    padding: str = "SAME"
    act: str = "relu6"

    def init(self, key, in_shape: Shape):
        n, h, w, c = in_shape
        kw_, kb = jax.random.split(key)
        fan_in = self.kh * self.kw
        weight = jax.random.normal(kw_, (self.kh, self.kw, c), jnp.float32)
        weight = weight * (2.0 / fan_in) ** 0.5
        bias = 0.01 * jax.random.normal(kb, (c,), jnp.float32)
        ho, wo = _out_hw(h, w, self.kh, self.stride, self.padding)
        return {"w": weight, "b": bias}, (n, ho, wo, c)

    def apply(self, params, x, use_pallas: bool = True):
        fn = dw.depthwise_conv2d if use_pallas else ref.depthwise_conv2d
        return fn(
            x, params["w"], params["b"],
            stride=self.stride, padding=self.padding, act=self.act,
        )

    def flops(self, in_shape: Shape) -> int:
        n, h, w, c = in_shape
        ho, wo = _out_hw(h, w, self.kh, self.stride, self.padding)
        return 2 * n * ho * wo * self.kh * self.kw * c

    def param_count(self, in_shape: Shape) -> int:
        c = in_shape[-1]
        return self.kh * self.kw * c + c

    def mxu_util(self, in_shape: Shape) -> float:
        return VPU_FALLBACK_UTILIZATION


@dataclass
class Pool:
    """Average or max pooling (pure data reduction — VPU path)."""

    kind: str = "max"  # "max" | "avg"
    window: int = 2
    stride: int = 2
    padding: str = "VALID"

    def init(self, key, in_shape: Shape):
        n, h, w, c = in_shape
        ho, wo = _out_hw(h, w, self.window, self.stride, self.padding)
        return {}, (n, ho, wo, c)

    def apply(self, params, x, use_pallas: bool = True):
        fn = ref.max_pool if self.kind == "max" else ref.avg_pool
        return fn(x, window=self.window, stride=self.stride, padding=self.padding)

    def flops(self, in_shape: Shape) -> int:
        n, h, w, c = in_shape
        ho, wo = _out_hw(h, w, self.window, self.stride, self.padding)
        return n * ho * wo * c * self.window * self.window

    def param_count(self, in_shape: Shape) -> int:
        return 0

    def mxu_util(self, in_shape: Shape) -> float:
        return VPU_FALLBACK_UTILIZATION


@dataclass
class GlobalAvgPool:
    """NHWC -> NC global average pooling."""

    def init(self, key, in_shape: Shape):
        n, h, w, c = in_shape
        return {}, (n, c)

    def apply(self, params, x, use_pallas: bool = True):
        return ref.global_avg_pool(x)

    def flops(self, in_shape: Shape) -> int:
        n, h, w, c = in_shape
        return n * h * w * c

    def param_count(self, in_shape: Shape) -> int:
        return 0

    def mxu_util(self, in_shape: Shape) -> float:
        return VPU_FALLBACK_UTILIZATION


@dataclass
class Dense:
    """Fully connected layer on (N, C) activations."""

    cout: int
    act: str = "none"

    def init(self, key, in_shape: Shape):
        n, cin = in_shape
        kw_, kb = jax.random.split(key)
        weight = jax.random.normal(kw_, (cin, self.cout), jnp.float32)
        weight = weight * (2.0 / cin) ** 0.5
        bias = 0.01 * jax.random.normal(kb, (self.cout,), jnp.float32)
        return {"w": weight, "b": bias}, (n, self.cout)

    def apply(self, params, x, use_pallas: bool = True):
        fn = mm.matmul if use_pallas else ref.matmul
        return fn(x, params["w"], params["b"], act=self.act)

    def flops(self, in_shape: Shape) -> int:
        n, cin = in_shape
        return 2 * n * cin * self.cout

    def param_count(self, in_shape: Shape) -> int:
        return in_shape[-1] * self.cout + self.cout

    def mxu_util(self, in_shape: Shape) -> float:
        n, cin = in_shape
        # batch-1 inference: M=N → the array is almost empty (late-layer effect)
        return mm.mxu_utilization(n, self.cout, cin)


@dataclass
class Residual:
    """x + f(x). The inner sequence must preserve the activation shape."""

    inner: List = field(default_factory=list)

    def init(self, key, in_shape: Shape):
        params, shape = init_sequence(key, self.inner, in_shape)
        if shape != in_shape:
            raise ValueError(f"residual inner changes shape {in_shape} -> {shape}")
        return {"inner": params}, in_shape

    def apply(self, params, x, use_pallas: bool = True):
        return x + apply_sequence(self.inner, params["inner"], x, use_pallas)

    def flops(self, in_shape: Shape) -> int:
        total = int(jnp.prod(jnp.array(in_shape)))  # the add
        return total + flops_sequence(self.inner, in_shape)

    def param_count(self, in_shape: Shape) -> int:
        return params_sequence(self.inner, in_shape)

    def mxu_util(self, in_shape: Shape) -> float:
        return util_sequence(self.inner, in_shape)


@dataclass
class Branch:
    """Parallel branches combined by channel-concat or add (inception/fire)."""

    branches: List[List] = field(default_factory=list)
    combine: str = "concat"  # "concat" | "add"

    def init(self, key, in_shape: Shape):
        keys = jax.random.split(key, len(self.branches))
        params, shapes = [], []
        for k, br in zip(keys, self.branches):
            p, s = init_sequence(k, br, in_shape)
            params.append(p)
            shapes.append(s)
        if self.combine == "add":
            if any(s != shapes[0] for s in shapes):
                raise ValueError(f"add-combine with mismatched shapes {shapes}")
            out = shapes[0]
        else:
            base = shapes[0][:-1]
            if any(s[:-1] != base for s in shapes):
                raise ValueError(f"concat-combine with mismatched spatial {shapes}")
            out = base + (sum(s[-1] for s in shapes),)
        return {"branches": params}, out

    def apply(self, params, x, use_pallas: bool = True):
        outs = [
            apply_sequence(br, p, x, use_pallas)
            for br, p in zip(self.branches, params["branches"])
        ]
        if self.combine == "add":
            out = outs[0]
            for o in outs[1:]:
                out = out + o
            return out
        return jnp.concatenate(outs, axis=-1)

    def flops(self, in_shape: Shape) -> int:
        return sum(flops_sequence(br, in_shape) for br in self.branches)

    def param_count(self, in_shape: Shape) -> int:
        return sum(params_sequence(br, in_shape) for br in self.branches)

    def mxu_util(self, in_shape: Shape) -> float:
        return util_sequence_multi(self.branches, in_shape)

    def out_shape(self, in_shape: Shape) -> Shape:
        shapes = [shape_sequence(br, in_shape) for br in self.branches]
        if self.combine == "add":
            return shapes[0]
        return shapes[0][:-1] + (sum(s[-1] for s in shapes),)


# ---------------------------------------------------------------------------
# Sequence helpers (used by segments, Residual, Branch)
# ---------------------------------------------------------------------------

def init_sequence(key, layers, in_shape: Shape):
    params = []
    shape = in_shape
    keys = jax.random.split(key, max(1, len(layers)))
    for k, layer in zip(keys, layers):
        p, shape = layer.init(k, shape)
        params.append(p)
    return params, shape


def apply_sequence(layers, params, x, use_pallas: bool = True):
    for layer, p in zip(layers, params):
        x = layer.apply(p, x, use_pallas)
    return x


def shape_sequence(layers, in_shape: Shape) -> Shape:
    shape = in_shape
    for layer in layers:
        _, shape = layer.init(jax.random.PRNGKey(0), shape)
    return shape


def flops_sequence(layers, in_shape: Shape) -> int:
    total = 0
    shape = in_shape
    for layer in layers:
        total += layer.flops(shape)
        _, shape = layer.init(jax.random.PRNGKey(0), shape)
    return total


def params_sequence(layers, in_shape: Shape) -> int:
    total = 0
    shape = in_shape
    for layer in layers:
        total += layer.param_count(shape)
        _, shape = layer.init(jax.random.PRNGKey(0), shape)
    return total


def util_sequence(layers, in_shape: Shape) -> float:
    """FLOP-weighted mean MXU utilization of a layer sequence."""
    total_flops = 0
    weighted = 0.0
    shape = in_shape
    for layer in layers:
        f = layer.flops(shape)
        weighted += f * layer.mxu_util(shape)
        total_flops += f
        _, shape = layer.init(jax.random.PRNGKey(0), shape)
    if total_flops == 0:
        return VPU_FALLBACK_UTILIZATION
    return weighted / total_flops


def util_sequence_multi(branches, in_shape: Shape) -> float:
    total_flops = 0
    weighted = 0.0
    for br in branches:
        f = flops_sequence(br, in_shape)
        weighted += f * util_sequence(br, in_shape)
        total_flops += f
    if total_flops == 0:
        return VPU_FALLBACK_UTILIZATION
    return weighted / total_flops
