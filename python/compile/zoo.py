"""The nine-model CNN zoo mirroring the paper's Table II.

Each architecture is expressed as a list of *segments* — the unit of
TPU/CPU partitioning. Segment boundaries are the paper's candidate
partition points: model ``i`` with ``P_i`` partition points has ``P_i``
segments, a prefix ``[1:p]`` runs on the TPU and the suffix ``[p+1:P]``
on the CPU (``p=0`` → all-CPU, ``p=P_i`` → all-TPU).

The architectures are scaled-down analogues (64×64×3 inputs, reduced
widths) of the real networks: the *structure* (fire modules, inverted
residuals, dense blocks, inception branches, separable convs) is faithful,
while absolute sizes are scaled so AOT + tests run in minutes on one CPU
core. The manifest maps each model's real (scaled) per-segment FLOPs/bytes
onto the paper's Table II totals — see :mod:`manifest`.
"""

from __future__ import annotations

from typing import Dict, List

from .layers import Branch, Conv, DWConv, Dense, GlobalAvgPool, Pool, Residual

INPUT_SHAPE = (1, 64, 64, 3)
NUM_CLASSES = 10

# Paper Table II: name -> (size MB, FLOPs G, partition points)
TABLE_II: Dict[str, tuple] = {
    "squeezenet": (1.4, 0.81, 2),
    "mobilenetv2": (4.1, 0.30, 5),
    "efficientnet": (6.7, 0.39, 6),
    "mnasnet": (7.1, 0.31, 7),
    "gpunet": (12.2, 0.62, 5),
    "densenet201": (19.7, 4.32, 7),
    "resnet50v2": (25.3, 4.49, 8),
    "xception": (26.1, 8.38, 11),
    "inceptionv4": (43.2, 12.27, 11),
}


def _head(classes: int = NUM_CLASSES) -> List:
    return [GlobalAvgPool(), Dense(classes)]


def _fire(squeeze: int, expand: int) -> List:
    """SqueezeNet fire module: 1x1 squeeze then parallel 1x1/3x3 expand."""
    return [
        Conv(1, 1, squeeze),
        Branch([[Conv(1, 1, expand)], [Conv(3, 3, expand)]], combine="concat"),
    ]


def _inverted_residual(cin: int, cout: int, t: int, stride: int = 1) -> List:
    """MobileNetV2 inverted residual (expand -> depthwise -> project)."""
    inner = [
        Conv(1, 1, cin * t, act="relu6"),
        DWConv(3, 3, stride=stride, act="relu6"),
        Conv(1, 1, cout, act="none"),
    ]
    if stride == 1 and cin == cout:
        return [Residual(inner)]
    return inner


def _bottleneck(c: int) -> List:
    """ResNet50V2 bottleneck block (identity variant)."""
    return [
        Residual([
            Conv(1, 1, c // 4),
            Conv(3, 3, c // 4),
            Conv(1, 1, c, act="none"),
        ])
    ]


def _dense_layer(growth: int) -> List:
    """DenseNet composite layer: concat(x, BN-relu-conv path)."""
    return [
        Branch([[], [Conv(1, 1, 4 * growth), Conv(3, 3, growth)]], combine="concat")
    ]


def _sep(cout: int, stride: int = 1) -> List:
    """Xception separable conv: depthwise + pointwise."""
    return [DWConv(3, 3, stride=stride, act="none"), Conv(1, 1, cout)]


def _inception_a(c: int) -> List:
    """Inception-style mixed block with four parallel branches."""
    return [
        Branch(
            [
                [Conv(1, 1, c)],
                [Conv(1, 1, c), Conv(3, 3, c)],
                [Conv(1, 1, c), Conv(3, 3, c), Conv(3, 3, c)],
                [Pool("avg", 3, 1, "SAME"), Conv(1, 1, c)],
            ],
            combine="concat",
        )
    ]


def squeezenet() -> List[List]:
    """2 segments."""
    return [
        [Conv(3, 3, 24, stride=2), Pool("max", 3, 2, "SAME")] + _fire(8, 16) + _fire(8, 16),
        _fire(16, 32) + [Conv(1, 1, NUM_CLASSES)] + [GlobalAvgPool()],
    ]


def mobilenetv2() -> List[List]:
    """5 segments."""
    return [
        [Conv(3, 3, 16, stride=2, act="relu6")] + _inverted_residual(16, 16, 1),
        _inverted_residual(16, 24, 4, stride=2) + _inverted_residual(24, 24, 4),
        _inverted_residual(24, 32, 4, stride=2) + _inverted_residual(32, 32, 4),
        _inverted_residual(32, 64, 4, stride=2) + _inverted_residual(64, 64, 4),
        _inverted_residual(64, 96, 4) + [Conv(1, 1, 128, act="relu6")] + _head(),
    ]


def efficientnet() -> List[List]:
    """6 segments."""
    return [
        [Conv(3, 3, 16, stride=2, act="relu6")] + _inverted_residual(16, 16, 1),
        _inverted_residual(16, 24, 4, stride=2),
        _inverted_residual(24, 24, 4) + _inverted_residual(24, 40, 4, stride=2),
        _inverted_residual(40, 40, 4) + _inverted_residual(40, 80, 4, stride=2),
        _inverted_residual(80, 80, 4) + _inverted_residual(80, 112, 4),
        [Conv(1, 1, 160, act="relu6")] + _head(),
    ]


def mnasnet() -> List[List]:
    """7 segments."""
    return [
        [Conv(3, 3, 16, stride=2), DWConv(3, 3), Conv(1, 1, 16, act="none")],
        _inverted_residual(16, 24, 3, stride=2),
        _inverted_residual(24, 24, 3) + _inverted_residual(24, 40, 3, stride=2),
        _inverted_residual(40, 40, 3),
        _inverted_residual(40, 80, 6, stride=2) + _inverted_residual(80, 80, 6),
        _inverted_residual(80, 96, 6),
        [Conv(1, 1, 160, act="relu6")] + _head(),
    ]


def gpunet() -> List[List]:
    """5 segments — a wide, plain-conv GPU-friendly design."""
    return [
        [Conv(3, 3, 32, stride=2), Conv(3, 3, 32)],
        [Conv(3, 3, 64, stride=2), Conv(3, 3, 64)],
        [Conv(3, 3, 96, stride=2)] + _bottleneck(96),
        [Conv(3, 3, 128, stride=2)] + _bottleneck(128),
        [Conv(1, 1, 192)] + _head(),
    ]


def densenet201() -> List[List]:
    """7 segments of dense blocks with transition layers."""
    g = 12
    trans = lambda c: [Conv(1, 1, c), Pool("avg", 2, 2)]
    return [
        [Conv(3, 3, 24, stride=2), Pool("max", 3, 2, "SAME")] + _dense_layer(g) + _dense_layer(g),
        _dense_layer(g) + _dense_layer(g) + trans(32),
        _dense_layer(g) + _dense_layer(g) + _dense_layer(g),
        _dense_layer(g) + _dense_layer(g) + trans(48),
        _dense_layer(g) + _dense_layer(g) + _dense_layer(g),
        _dense_layer(g) + _dense_layer(g) + trans(64),
        _dense_layer(g) + _dense_layer(g) + _head(),
    ]


def resnet50v2() -> List[List]:
    """8 segments of bottleneck stacks."""
    return [
        [Conv(7, 7, 32, stride=2), Pool("max", 3, 2, "SAME")],
        _bottleneck(32) + _bottleneck(32),
        [Conv(3, 3, 64, stride=2)] + _bottleneck(64),
        _bottleneck(64) + _bottleneck(64),
        [Conv(3, 3, 96, stride=2)] + _bottleneck(96),
        _bottleneck(96) + _bottleneck(96),
        [Conv(3, 3, 128, stride=2)] + _bottleneck(128) + _bottleneck(128),
        _bottleneck(128) + _head(),
    ]


def xception() -> List[List]:
    """11 segments of separable-conv residual stacks."""
    def block(c, stride=2):
        return [Conv(1, 1, c, stride=stride, act="none")] + _sep(c) + _sep(c)

    def res_block(c):
        return [Residual(_sep(c) + _sep(c))]

    return [
        [Conv(3, 3, 16, stride=2), Conv(3, 3, 32)],
        block(32),
        block(48),
        res_block(48),
        res_block(48),
        [Conv(1, 1, 64, stride=2, act="none")] + _sep(64),
        res_block(64),
        res_block(64),
        res_block(64),
        block(96, stride=2)[:3],
        _sep(128) + _head(),
    ]


def inceptionv4() -> List[List]:
    """11 segments: stem + inception-A/B stacks + reductions."""
    return [
        [Conv(3, 3, 16, stride=2), Conv(3, 3, 24), Pool("max", 3, 2, "SAME")],
        [Conv(1, 1, 24), Conv(3, 3, 32)],
        _inception_a(16),
        _inception_a(16),
        [Conv(3, 3, 64, stride=2)],  # reduction-A
        _inception_a(24),
        _inception_a(24),
        _inception_a(24),
        [Conv(3, 3, 96, stride=2)],  # reduction-B
        _inception_a(32),
        _inception_a(32) + _head(),
    ]


BUILDERS = {
    "squeezenet": squeezenet,
    "mobilenetv2": mobilenetv2,
    "efficientnet": efficientnet,
    "mnasnet": mnasnet,
    "gpunet": gpunet,
    "densenet201": densenet201,
    "resnet50v2": resnet50v2,
    "xception": xception,
    "inceptionv4": inceptionv4,
}


def model_names() -> List[str]:
    return list(BUILDERS)


def build(name: str) -> List[List]:
    if name not in BUILDERS:
        raise KeyError(f"unknown model {name!r}; have {sorted(BUILDERS)}")
    segments = BUILDERS[name]()
    expected = TABLE_II[name][2]
    if len(segments) != expected:
        raise AssertionError(
            f"{name}: built {len(segments)} segments, Table II says {expected}"
        )
    return segments
