"""Layer-2 model construction: parameters, per-segment functions, metadata.

A :class:`ModelDef` holds the segment list from :mod:`zoo`, deterministic
parameters, and per-segment metadata (shapes, FLOPs, parameter counts, MXU
utilization). ``segment_fn`` returns the closure that :mod:`aot` lowers to
one HLO artifact per segment — weights are captured as constants so each
artifact is self-contained (input: the activation tensor; output: the next
activation), which is exactly what the rust runtime composes at serve time.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import zoo

Shape = Tuple[int, ...]


@dataclass
class SegmentInfo:
    index: int
    in_shape: Shape
    out_shape: Shape
    flops: int
    param_count: int
    mxu_util: float


@dataclass
class ModelDef:
    name: str
    segments: List[List]          # layer lists
    params: List[List]            # per-segment parameter pytrees
    infos: List[SegmentInfo]

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    @property
    def input_shape(self) -> Shape:
        return self.infos[0].in_shape

    @property
    def output_shape(self) -> Shape:
        return self.infos[-1].out_shape

    def apply_segment(self, i: int, x, use_pallas: bool = True):
        return L.apply_sequence(self.segments[i], self.params[i], x, use_pallas)

    def apply_range(self, a: int, b: int, x, use_pallas: bool = True):
        """Apply segments [a, b) in order."""
        for i in range(a, b):
            x = self.apply_segment(i, x, use_pallas)
        return x

    def apply_full(self, x, use_pallas: bool = True):
        return self.apply_range(0, self.num_segments, x, use_pallas)


def build_model(name: str, seed: int = 0) -> ModelDef:
    """Build + initialize a zoo model; deterministic for a given seed."""
    segments = zoo.build(name)
    # zlib.crc32 (not built-in hash(), which is salted per-process) so that
    # weights are bit-identical across every python invocation.
    name_id = zlib.crc32(name.encode()) % (2**31)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), name_id)
    params: List[List] = []
    infos: List[SegmentInfo] = []
    shape: Shape = zoo.INPUT_SHAPE
    for i, seg in enumerate(segments):
        key, sub = jax.random.split(key)
        in_shape = shape
        p, shape = L.init_sequence(sub, seg, in_shape)
        params.append(p)
        infos.append(
            SegmentInfo(
                index=i,
                in_shape=in_shape,
                out_shape=shape,
                flops=L.flops_sequence(seg, in_shape),
                param_count=L.params_sequence(seg, in_shape),
                mxu_util=L.util_sequence(seg, in_shape),
            )
        )
    return ModelDef(name=name, segments=segments, params=params, infos=infos)


def segment_fn(model: ModelDef, i: int, use_pallas: bool = True) -> Callable:
    """A jit-lowerable function for segment ``i`` with captured weights.

    Returns a 1-tuple (lowered with ``return_tuple=True``; the rust loader
    unwraps with ``to_tuple1``).
    """

    def fn(x):
        return (model.apply_segment(i, x, use_pallas),)

    return fn


def tensor_bytes(shape: Shape, dtype_bytes: int = 1) -> int:
    """Simulated on-wire tensor size (int8, as the paper's quantized models)."""
    total = 1
    for d in shape:
        total *= d
    return total * dtype_bytes


def scaled_manifest_entry(model: ModelDef) -> dict:
    """Manifest entry mapping this (scaled) model onto the paper's Table II.

    ``sim_*`` fields carry the Table II magnitudes, distributed across
    segments proportionally to the real (scaled) model's per-segment
    parameter counts / FLOPs — preserving the within-model shape that
    drives partitioning decisions. ``real_*`` fields describe the actual
    artifacts the runtime executes.
    """
    size_mb, flops_g, ppoints = zoo.TABLE_II[model.name]
    total_params = sum(s.param_count for s in model.infos) or 1
    total_flops = sum(s.flops for s in model.infos) or 1
    sim_bytes_total = int(size_mb * 1e6)
    sim_flops_total = int(flops_g * 1e9)

    segs = []
    for info in model.infos:
        segs.append(
            {
                "index": info.index,
                "artifact": f"{model.name}/seg{info.index}.hlo.txt",
                "in_shape": list(info.in_shape),
                "out_shape": list(info.out_shape),
                "real_flops": info.flops,
                "real_param_count": info.param_count,
                "real_param_bytes": info.param_count * 4,
                "sim_weight_bytes": int(
                    sim_bytes_total * info.param_count / total_params
                ),
                "sim_flops": int(sim_flops_total * info.flops / total_flops),
                "in_bytes": tensor_bytes(info.in_shape),
                "out_bytes": tensor_bytes(info.out_shape),
                "mxu_util": round(info.mxu_util, 6),
            }
        )

    return {
        "name": model.name,
        "partition_points": ppoints,
        "table_size_mb": size_mb,
        "table_flops_g": flops_g,
        "input_shape": list(model.input_shape),
        "output_shape": list(model.output_shape),
        "segments": segs,
    }
