"""Convolution as im2col + the MXU-tiled Pallas matmul.

This mirrors how the Edge TPU actually executes convolutions: the systolic
array only multiplies matrices, so the compiler rewrites each conv into
patch-extraction (data movement) followed by a weight-stationary matmul.
The matmul — the hot-spot — is the Pallas kernel in :mod:`matmul`; patch
extraction is pure data movement and stays in XLA where it fuses with the
surrounding reshape/transpose ops.

VMEM accounting (DESIGN.md §4): the matmul sees M = N*Ho*Wo rows and
K = kh*kw*Cin contracting size. For every conv in the model zoo the chosen
block shapes keep one (x, w, acc) block triple under the 8 MB budget —
asserted by :func:`check_vmem` at AOT time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import matmul as mm

# The Edge TPU analogue: on-chip scratchpad budget for one kernel step.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _out_dim(size: int, k: int, stride: int, padding: str) -> int:
    if padding == "SAME":
        return -(-size // stride)
    return (size - k) // stride + 1


def im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: str) -> jax.Array:
    """f32[N,H,W,C] -> f32[N*Ho*Wo, kh*kw*C] patch matrix."""
    n, h, w, c = x.shape
    ho = _out_dim(h, kh, stride, padding)
    wo = _out_dim(w, kw, stride, padding)
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # patches feature dim is ordered C * kh * kw (channel-major); reorder to
    # kh*kw*C to match HWIO weight reshape.
    patches = patches.reshape(n, ho, wo, c, kh * kw)
    patches = jnp.swapaxes(patches, 3, 4)
    return patches.reshape(n * ho * wo, kh * kw * c)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    act: str = "none",
) -> jax.Array:
    """NHWC convolution through the Pallas matmul kernel.

    Args:
      x: f32[N, H, W, Cin].
      w: f32[kh, kw, Cin, Cout] (HWIO).
      bias: optional f32[Cout], fused.
      act: fused activation (``none | relu | relu6 | sigmoid``).
    """
    n, h, w_in, cin = x.shape
    kh, kw, wcin, cout = w.shape
    if wcin != cin:
        raise ValueError(f"channel mismatch: x has {cin}, w has {wcin}")
    ho = _out_dim(h, kh, stride, padding)
    wo = _out_dim(w_in, kw, stride, padding)

    cols = im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(kh * kw * cin, cout)
    out = mm.matmul(cols, wmat, bias, act=act)
    return out.reshape(n, ho, wo, cout)


def matmul_dims(in_shape, kh: int, kw: int, cout: int, stride: int, padding: str):
    """(M, K, N) of the underlying matmul for cost/utilization estimates."""
    n, h, w, cin = in_shape
    ho = _out_dim(h, kh, stride, padding)
    wo = _out_dim(w, kw, stride, padding)
    return n * ho * wo, kh * kw * cin, cout


def check_vmem(in_shape, kh: int, kw: int, cout: int, stride: int, padding: str) -> int:
    """VMEM bytes for one kernel step; raises if over budget."""
    m, k, n = matmul_dims(in_shape, kh, kw, cout, stride, padding)
    bm = min(mm.BLOCK_M, max(8, m))
    bn = min(mm.BLOCK_N, max(8, n))
    bk = min(mm.BLOCK_K, max(8, k))
    bytes_ = mm.vmem_bytes(bm, bn, bk)
    if bytes_ > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"conv block ({bm},{bn},{bk}) needs {bytes_} B VMEM > {VMEM_BUDGET_BYTES}"
        )
    return bytes_


def mxu_utilization(in_shape, kh: int, kw: int, cout: int, stride: int, padding: str) -> float:
    """Systolic-array fill fraction of this conv — drives the TPU cost model."""
    m, k, n = matmul_dims(in_shape, kh, kw, cout, stride, padding)
    return mm.mxu_utilization(m, n, k)
