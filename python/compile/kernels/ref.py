"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

These implementations use only ``jax.numpy`` / ``jax.lax`` primitives and are
deliberately written in the most obvious way possible. ``python/tests``
asserts each Pallas kernel against these within float32 tolerance across
hypothesis-generated shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(x, act: str):
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {act!r}")


def matmul(x, w, bias=None, *, act: str = "none"):
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        out = out + bias[None, :]
    return _act(out, act)


def conv2d(x, w, bias=None, *, stride: int = 1, padding: str = "SAME", act: str = "none"):
    """NHWC conv. x: f32[N,H,W,Cin], w: f32[kh,kw,Cin,Cout]."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if bias is not None:
        out = out + bias[None, None, None, :]
    return _act(out, act)


def depthwise_conv2d(x, w, bias=None, *, stride: int = 1, padding: str = "SAME", act: str = "none"):
    """Depthwise NHWC conv. x: f32[N,H,W,C], w: f32[kh,kw,C]."""
    c = x.shape[-1]
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w[:, :, None, :].astype(jnp.float32),  # HWIO with I=1 per group
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    if bias is not None:
        out = out + bias[None, None, None, :]
    return _act(out, act)


def avg_pool(x, *, window: int, stride: int, padding: str = "VALID"):
    """NHWC average pool."""
    out = jax.lax.reduce_window(
        x.astype(jnp.float32),
        0.0,
        jax.lax.add,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding=padding,
    )
    return out / float(window * window)


def max_pool(x, *, window: int, stride: int, padding: str = "VALID"):
    """NHWC max pool."""
    return jax.lax.reduce_window(
        x.astype(jnp.float32),
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding=padding,
    )


def global_avg_pool(x):
    return jnp.mean(x.astype(jnp.float32), axis=(1, 2))
