"""Depthwise-convolution Pallas kernel.

Depthwise convs have no cross-channel reuse, so on the Edge TPU they cannot
fill the systolic array — they execute on the VPU-like elementwise path.
That is exactly why the paper's Fig. 3 finds late / depthwise-heavy segments
run as well on the CPU as on the TPU (the collaborative-processing
opportunity). We keep the kernel faithful to that structure: a grid over
channel blocks, each step doing kh*kw shifted multiply-accumulates — an
elementwise schedule, not an MXU one.

The mxu_utilization of a depthwise layer is therefore reported as the VPU
fallback constant (~0.04 of MXU peak), which the rust TPU cost model uses
to derive the Fig. 3 speedup shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Effective throughput vs MXU peak when a layer falls off the systolic array.
VPU_FALLBACK_UTILIZATION = 0.04

BLOCK_C = 128


def _dw_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, stride, ho, wo, act):
    """One channel-block: out[ho, wo, bc] = sum_ij x[i::s, j::s, :] * w[i, j, :]."""
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for i in range(kh):
        for j in range(kw):
            window = jax.lax.dynamic_slice(
                x, (i, j, 0), (1 + (ho - 1) * stride, 1 + (wo - 1) * stride, x.shape[2])
            )
            acc += window[::stride, ::stride, :] * w[i, j, :][None, None, :]
    acc += b_ref[...][None, None, :]
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif act == "relu6":
        acc = jnp.clip(acc, 0.0, 6.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("stride", "padding", "act", "block_c"))
def depthwise_conv2d(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    act: str = "none",
    block_c: int = BLOCK_C,
) -> jax.Array:
    """Depthwise NHWC conv via Pallas. x: f32[N,H,W,C], w: f32[kh,kw,C]."""
    n, h, w_in, c = x.shape
    kh, kw, wc = w.shape
    if wc != c:
        raise ValueError(f"channel mismatch: x has {c}, w has {wc}")
    if act not in ("none", "relu", "relu6"):
        raise ValueError(f"unsupported fused activation {act!r}")

    if padding == "SAME":
        ho = -(-h // stride)
        wo = -(-w_in // stride)
        pad_h = max(0, (ho - 1) * stride + kh - h)
        pad_w = max(0, (wo - 1) * stride + kw - w_in)
        x = jnp.pad(
            x,
            (
                (0, 0),
                (pad_h // 2, pad_h - pad_h // 2),
                (pad_w // 2, pad_w - pad_w // 2),
                (0, 0),
            ),
        )
    elif padding == "VALID":
        ho = (h - kh) // stride + 1
        wo = (w_in - kw) // stride + 1
    else:
        raise ValueError(f"unknown padding {padding!r}")

    bias = jnp.zeros((c,), jnp.float32) if bias is None else bias.astype(jnp.float32)

    bc = min(block_c, c)
    rem = (-c) % bc
    if rem:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, rem)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, rem)))
        bias = jnp.pad(bias, (0, rem))
    cp = x.shape[-1]
    hp, wp = x.shape[1], x.shape[2]

    kern = functools.partial(
        _dw_kernel, kh=kh, kw=kw, stride=stride, ho=ho, wo=wo, act=act
    )

    def one_image(xi):
        return pl.pallas_call(
            kern,
            grid=(cp // bc,),
            in_specs=[
                pl.BlockSpec((hp, wp, bc), lambda i: (0, 0, i)),
                pl.BlockSpec((kh, kw, bc), lambda i: (0, 0, i)),
                pl.BlockSpec((bc,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((ho, wo, bc), lambda i: (0, 0, i)),
            out_shape=jax.ShapeDtypeStruct((ho, wo, cp), jnp.float32),
            interpret=True,
        )(xi.astype(jnp.float32), w.astype(jnp.float32), bias)

    out = jax.vmap(one_image)(x)
    return out[..., :c]
