"""MXU-tiled matmul Pallas kernel with fused bias + activation epilogue.

This is the compute hot-spot of every convolutional segment: on the Edge
TPU the systolic array consumes weight tiles streamed from SRAM, and we
express the identical schedule with a Pallas grid over (M, N, K) blocks.

Hardware-adaptation notes (DESIGN.md §4):
  * The MXU is a 128x128 systolic array — block sizes default to multiples
    of (8, 128) so a real-TPU lowering would map one block per MXU pass.
  * VMEM budget: one x-block (bm*bk), one w-block (bk*bn), one accumulator
    (bm*bn) must fit in ~8 MB together with double-buffering headroom.
    With the defaults (128, 128, 128) @ f32 that is 3 * 64 KiB per step,
    leaving VMEM for the pipelined next tiles — the same "weights stream
    through a small resident window" behaviour the Edge TPU's SRAM cache
    exhibits for over-sized models.
  * K is the innermost grid axis so the accumulator stays resident while
    weight tiles stream — minimizing HBM↔VMEM traffic exactly like the
    Edge TPU minimizes host↔SRAM swaps within one segment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned block shapes.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128

_ACTIVATIONS = ("none", "relu", "relu6", "sigmoid")


def _epilogue(acc, bias, act: str):
    if bias is not None:
        acc = acc + bias
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif act == "relu6":
        acc = jnp.clip(acc, 0.0, 6.0)
    elif act == "sigmoid":
        acc = jax.nn.sigmoid(acc)
    return acc


def _mm_kernel(x_ref, w_ref, o_ref, *, nk: int, act: str):
    """Grid = (M/bm, N/bn, K/bk); accumulate into o_ref across the K axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    if act != "none":

        @pl.when(pl.program_id(2) == nk - 1)
        def _act():
            o_ref[...] = _epilogue(o_ref[...], None, act)


def _mm_bias_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _fused():
        o_ref[...] = _epilogue(o_ref[...], b_ref[...][None, :], act)


def _pad_to(x, multiple: int, axis: int):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("act", "block_m", "block_n", "block_k")
)
def matmul(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    act: str = "none",
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
) -> jax.Array:
    """``act(x @ w + bias)`` via a Pallas MXU-tiled kernel.

    Args:
      x: f32[M, K] activations.
      w: f32[K, N] weights.
      bias: optional f32[N], fused into the final K-step.
      act: one of ``none | relu | relu6 | sigmoid`` — fused epilogue.

    Shapes are zero-padded up to block multiples and the result sliced back,
    so arbitrary (M, K, N) are accepted.
    """
    if act not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}; want one of {_ACTIVATIONS}")
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul wants rank-2 operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contracting dims differ: {x.shape} @ {w.shape}")

    m, k = x.shape
    _, n = w.shape
    # Shrink blocks for small problems so the grid is never empty.
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    bk = min(block_k, max(8, k))

    xp = _pad_to(_pad_to(x.astype(jnp.float32), bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), bk, 0), bn, 1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))

    if bias is None:
        kern = functools.partial(_mm_kernel, nk=grid[2], act=act)
        out = pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[x_spec, w_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, wp)
    else:
        if bias.shape != (n,):
            raise ValueError(f"bias shape {bias.shape} != ({n},)")
        bp = _pad_to(bias.astype(jnp.float32), bn, 0)
        b_spec = pl.BlockSpec((bn,), lambda i, j, kk: (j,))
        kern = functools.partial(_mm_bias_kernel, nk=grid[2], act=act)
        out = pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[x_spec, w_spec, b_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, wp, bp)

    return out[:m, :n]


def vmem_bytes(block_m: int = BLOCK_M, block_n: int = BLOCK_N, block_k: int = BLOCK_K) -> int:
    """Estimated VMEM residency of one grid step (f32, double-buffered inputs)."""
    x_blk = block_m * block_k * 4
    w_blk = block_k * block_n * 4
    acc = block_m * block_n * 4
    return 2 * (x_blk + w_blk) + acc


def mxu_utilization(m: int, n: int, k: int) -> float:
    """Fraction of the 128x128 MXU a (m, n, k) matmul keeps busy.

    Mirrors the Edge TPU's systolic-array behaviour: small N/M (late, narrow
    layers) underfill the array — the root of Fig. 3's 'late layers run as
    well on the CPU' observation.
    """
    fill_m = min(m, 128) / 128.0
    fill_n = min(n, 128) / 128.0
    # K only pipelines; below 128 the array drains early.
    fill_k = min(k, 128) / 128.0
    return max(1e-3, fill_m * fill_n * (0.5 + 0.5 * fill_k))
