"""Layer-1 Pallas kernels (build-time only; never on the request path).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness path and the
real-TPU performance is estimated analytically (see DESIGN.md §8).

Kernels:
  - :mod:`matmul`    — MXU-tiled matmul with fused bias + activation epilogue.
  - :mod:`conv2d`    — convolution as im2col + the tiled matmul kernel.
  - :mod:`depthwise` — per-channel (depthwise) convolution.
  - :mod:`ref`       — pure-jnp oracles every kernel is tested against.
"""

from . import matmul, conv2d, depthwise, ref  # noqa: F401
