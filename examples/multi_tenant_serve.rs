//! End-to-end driver (EXPERIMENTS.md §E2E): serve a multi-tenant mix of
//! real models through the full three-layer stack — Pallas-kernel HLO
//! artifacts executed via PJRT, SwapLess partitioning, per-model CPU
//! pools — under open-loop Poisson load, and report latency/throughput
//! for the SwapLess plan vs the Edge-TPU-compiler baseline.
//!
//! ```bash
//! cargo run --release --example multi_tenant_serve
//! ```

use std::time::{Duration, Instant};

use swapless::alloc;
use swapless::analytic::{AnalyticModel, Config, Tenant};
use swapless::config::HardwareSpec;
use swapless::coordinator::{Server, ServerOptions};
use swapless::model::Manifest;
use swapless::tpu::CostModel;
use swapless::util::rng::Rng;

const MODELS: [&str; 3] = ["mobilenetv2", "squeezenet", "efficientnet"];
const RATES: [f64; 3] = [8.0, 6.0, 4.0]; // requests/second, open loop
const DURATION_S: f64 = 12.0;

fn main() -> Result<(), String> {
    let manifest = Manifest::load("artifacts")?;
    let hw = HardwareSpec::default();
    let cost = CostModel::new(hw.clone());
    let am = AnalyticModel::new(cost.clone());
    let names: Vec<String> = MODELS.iter().map(|s| s.to_string()).collect();

    let tenants: Vec<Tenant> = MODELS
        .iter()
        .zip(RATES)
        .map(|(n, r)| {
            Ok(Tenant {
                model: manifest.get(n)?.clone(),
                rate: r,
            })
        })
        .collect::<Result<_, String>>()?;

    let swapless_plan = alloc::hill_climb(&am, &tenants, hw.cpu_cores);
    let compiler_plan = alloc::edge_tpu_compiler(&am, &tenants);
    println!("workload: {MODELS:?} @ {RATES:?} rps, {DURATION_S}s each config");
    println!(
        "swapless plan: P={:?} K={:?}",
        swapless_plan.config.partitions, swapless_plan.config.cores
    );
    println!(
        "compiler plan: P={:?} K={:?}",
        compiler_plan.config.partitions, compiler_plan.config.cores
    );

    for (label, cfg) in [
        ("edge-tpu-compiler", compiler_plan.config),
        ("swapless", swapless_plan.config),
    ] {
        run_config(&manifest, &names, &cost, cfg, label)?;
    }
    Ok(())
}

fn run_config(
    manifest: &Manifest,
    names: &[String],
    cost: &CostModel,
    cfg: Config,
    label: &str,
) -> Result<(), String> {
    let server = Server::start(
        manifest,
        names,
        cost.clone(),
        cfg,
        ServerOptions {
            adaptive: false,
            ..Default::default()
        },
    )
    .map_err(|e| e.to_string())?;

    // Open-loop Poisson generator per model (merged, single thread).
    let mut rng = Rng::new(7);
    let mut next_at: Vec<f64> = RATES
        .iter()
        .enumerate()
        .map(|(m, r)| rng.fork(m as u64).exponential(*r))
        .collect();
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut issued = 0usize;
    while t0.elapsed().as_secs_f64() < DURATION_S {
        let now = t0.elapsed().as_secs_f64();
        let (m, t_next) = next_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, t)| (i, *t))
            .unwrap();
        if t_next > DURATION_S {
            break;
        }
        if t_next > now {
            std::thread::sleep(Duration::from_secs_f64(t_next - now));
        }
        let n_in: usize = server.tenants()[m].model.input_shape.iter().product();
        pending.push(server.submit(m, vec![0.5; n_in]));
        issued += 1;
        next_at[m] += rng.exponential(RATES[m]);
    }
    // Drain.
    let mut errors = 0usize;
    for rx in pending {
        match rx.recv() {
            Ok(Ok(_)) => {}
            _ => errors += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!("\n[{label}] {issued} issued, {} completed, {errors} errors, {:.1} req/s", stats.completed, stats.completed as f64 / wall);
    for (i, h) in stats.per_model.iter().enumerate() {
        if h.count() > 0 {
            println!(
                "  {:<14} n={:<5} mean {:>7.1} ms   p50 {:>7.1}   p95 {:>7.1}   max {:>7.1}",
                names[i],
                h.count(),
                h.mean() * 1e3,
                h.percentile(50.0) * 1e3,
                h.percentile(95.0) * 1e3,
                h.max() * 1e3
            );
        }
    }
    Ok(())
}
