//! End-to-end driver (EXPERIMENTS.md §E2E): serve a multi-tenant mix of
//! real models through the full three-layer stack — tenants attached via
//! admission control, SwapLess partitioning, per-tenant CPU pools — under
//! open-loop Poisson load, and report latency/throughput for the SwapLess
//! plan vs the Edge-TPU-compiler baseline.
//!
//! Runs on a fresh checkout (synthetic manifest + emulated backend).
//!
//! ```bash
//! cargo run --release --example multi_tenant_serve
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use swapless::alloc;
use swapless::analytic::{AnalyticModel, Config, Tenant, TenantHandle};
use swapless::config::HardwareSpec;
use swapless::coordinator::{AttachOptions, ServerBuilder};
use swapless::model::{Manifest, ModelMeta};
use swapless::tpu::CostModel;
use swapless::util::rng::Rng;

const MODELS: [&str; 3] = ["mobilenetv2", "squeezenet", "efficientnet"];
const RATES: [f64; 3] = [8.0, 6.0, 4.0]; // requests/second, open loop
const DURATION_S: f64 = 12.0;

fn main() -> Result<(), String> {
    let manifest = Manifest::load_or_synthetic("artifacts");
    let hw = HardwareSpec::default();
    let cost = CostModel::new(hw.clone());
    let am = AnalyticModel::new(cost.clone());

    let tenants: Vec<Tenant> = MODELS
        .iter()
        .zip(RATES)
        .map(|(n, r)| {
            Ok(Tenant {
                model: manifest.get(n)?.clone(),
                rate: r,
            })
        })
        .collect::<Result<_, String>>()?;

    let swapless_plan = alloc::hill_climb(&am, &tenants, hw.cpu_cores);
    let compiler_plan = alloc::edge_tpu_compiler(&am, &tenants);
    println!("workload: {MODELS:?} @ {RATES:?} rps, {DURATION_S}s each config");
    println!(
        "swapless plan: P={:?} K={:?}",
        swapless_plan.config.partitions, swapless_plan.config.cores
    );
    println!(
        "compiler plan: P={:?} K={:?}",
        compiler_plan.config.partitions, compiler_plan.config.cores
    );

    for (label, cfg) in [
        ("edge-tpu-compiler", compiler_plan.config),
        ("swapless", swapless_plan.config),
    ] {
        run_config(&manifest, &hw, cfg, label)?;
    }
    Ok(())
}

fn run_config(
    manifest: &Manifest,
    hw: &HardwareSpec,
    cfg: Config,
    label: &str,
) -> Result<(), String> {
    let server = ServerBuilder::new(manifest, CostModel::new(hw.clone()))
        .k_max(hw.cpu_cores)
        .adaptive(false) // static config comparison
        .build()
        .map_err(|e| e.to_string())?;

    // Attach each tenant at its declared rate, then pin the config under
    // test (set_config validates dimensions against the tenant count).
    let mut handles: Vec<(TenantHandle, Arc<ModelMeta>)> = Vec::new();
    for (name, rate) in MODELS.iter().zip(RATES) {
        let h = server
            .attach(name, AttachOptions { rate_hint: rate, ..Default::default() })
            .map_err(|e| e.to_string())?;
        let meta = server.model_meta(h).expect("just attached");
        handles.push((h, meta));
    }
    server.set_config(cfg).map_err(|e| e.to_string())?;

    // Open-loop Poisson generator per model (merged, single thread).
    let mut rng = Rng::new(7);
    let mut next_at: Vec<f64> = RATES
        .iter()
        .enumerate()
        .map(|(m, r)| rng.fork(m as u64).exponential(*r))
        .collect();
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut issued = 0usize;
    while t0.elapsed().as_secs_f64() < DURATION_S {
        let now = t0.elapsed().as_secs_f64();
        let (m, t_next) = next_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, t)| (i, *t))
            .unwrap();
        if t_next > DURATION_S {
            break;
        }
        if t_next > now {
            std::thread::sleep(Duration::from_secs_f64(t_next - now));
        }
        let (h, meta) = &handles[m];
        let n_in: usize = meta.input_shape.iter().product();
        pending.push(server.submit(*h, vec![0.5; n_in]));
        issued += 1;
        next_at[m] += rng.exponential(RATES[m]);
    }
    // Drain the tickets (any failure arrives as a typed RequestError).
    let mut errors = 0usize;
    for ticket in pending {
        if ticket.wait().is_err() {
            errors += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!(
        "\n[{label}] {issued} issued, {} completed, {errors} errors, {:.1} req/s",
        stats.completed,
        stats.completed as f64 / wall
    );
    for t in &stats.per_tenant {
        if t.latency.count() > 0 {
            println!(
                "  {:<14} n={:<5} mean {:>7.1} ms   p50 {:>7.1}   p95 {:>7.1}   max {:>7.1}",
                t.name,
                t.latency.count(),
                t.latency.mean() * 1e3,
                t.latency.percentile(50.0) * 1e3,
                t.latency.percentile(95.0) * 1e3,
                t.latency.max() * 1e3
            );
        }
    }
    drop(server);
    Ok(())
}
