//! Quickstart: build an empty server, attach a tenant through admission
//! control, serve a few requests through the ticketed request lifecycle,
//! and detach — the tenant + request lifecycle APIs end to end.
//!
//! Works on a fresh checkout: without `make artifacts` a synthetic
//! paper-scale manifest and the emulated execution backend are used
//! automatically (CI runs this as a smoke test).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::time::Duration;

use swapless::analytic::AnalyticModel;
use swapless::config::HardwareSpec;
use swapless::coordinator::{AttachOptions, Request, RequestError, ServerBuilder};
use swapless::model::Manifest;
use swapless::tpu::CostModel;

fn main() -> Result<(), String> {
    // 1. Load the artifact manifest (synthetic fallback without artifacts).
    let manifest = Manifest::load_or_synthetic("artifacts");
    let model = "mobilenetv2";
    let meta = manifest.get(model)?.clone();
    println!(
        "{model}: {} segments, {:.1} MB (Table II scale), input {:?}",
        meta.partition_points, meta.table_size_mb, meta.input_shape
    );

    // 2. Build a server with zero tenants.
    let hw = HardwareSpec::default();
    let cost = CostModel::new(hw.clone());
    let server = ServerBuilder::new(&manifest, cost.clone())
        .k_max(hw.cpu_cores)
        .adaptive(true)
        .build()
        .map_err(|e| e.to_string())?;
    println!("backend: {:?}", server.backend());

    // 3. Attach the tenant at a declared 3 RPS. Admission control plans
    //    the mix with the analytic queueing model and installs the config.
    let handle = server
        .attach(model, AttachOptions { rate_hint: 3.0, ..Default::default() })
        .map_err(|e| e.to_string())?;
    let cfg = server.current_config();
    let am = AnalyticModel::new(cost);
    println!(
        "attached as {handle}: TPU prefix = {} of {} segments, {} CPU cores, predicted e2e {:.1} ms",
        cfg.partitions[0],
        meta.partition_points,
        cfg.cores[0],
        am.e2e_latency(&server.tenants(), &cfg, 0) * 1e3
    );

    // 4. Serve requests addressed by the stable handle. submit() takes a
    //    Request (input + optional class override / deadline / cancel
    //    token) and returns a Ticket — block on it, poll it, or cancel it.
    let n_in: usize = meta.input_shape.iter().product();
    for i in 0..5 {
        let ticket = server.submit(
            handle,
            Request::new(vec![0.5; n_in]).with_deadline(Duration::from_secs(5)),
        );
        let out = ticket.wait().map_err(|e| e.to_string())?;
        println!(
            "request {i}: {} outputs, first = {:.4}, latency {:.1} ms",
            out.output.len(),
            out.output[0],
            out.latency_s * 1e3
        );
    }
    // A bare input vector converts into a default Request, and a ticket
    // can be polled without blocking (wait_timeout / try_wait).
    let mut ticket = server.submit(handle, vec![0.5; n_in]);
    while ticket.try_wait().is_none() {
        std::thread::sleep(Duration::from_millis(1));
    }
    ticket.wait().map_err(|e| e.to_string())?;

    // 5. Detach: the final per-tenant histogram comes back.
    let stats = server.detach(handle).map_err(|e| e.to_string())?;
    println!(
        "detached {handle}: {} requests, mean {:.1} ms",
        stats.latency.count(),
        stats.latency.mean() * 1e3
    );
    // A detached handle resolves its ticket with a typed error — it
    // never panics, misroutes, or hangs.
    match server.submit(handle, vec![0.5; n_in]).wait() {
        Err(RequestError::NotAttached(h)) => {
            println!("request after detach fails typed (NotAttached({h})) — done.")
        }
        other => return Err(format!("expected NotAttached, got {other:?}")),
    }
    Ok(())
}
