//! Quickstart: load one model's AOT artifacts, plan a partition with the
//! analytic model, and serve a few requests through the full stack.
//!
//! ```bash
//! make artifacts            # once
//! cargo run --release --example quickstart
//! ```

use swapless::alloc;
use swapless::analytic::{AnalyticModel, Tenant};
use swapless::config::HardwareSpec;
use swapless::coordinator::{Server, ServerOptions};
use swapless::model::Manifest;
use swapless::tpu::CostModel;

fn main() -> Result<(), String> {
    // 1. Load the artifact manifest produced by `python -m compile.aot`.
    let manifest = Manifest::load("artifacts")?;
    let model = "mobilenetv2";
    let meta = manifest.get(model)?;
    println!(
        "{model}: {} segments, {:.1} MB (Table II scale), input {:?}",
        meta.partition_points, meta.table_size_mb, meta.input_shape
    );

    // 2. Ask the analytic queueing model for the best partition at 3 RPS.
    let hw = HardwareSpec::default();
    let am = AnalyticModel::new(CostModel::new(hw.clone()));
    let tenants = vec![Tenant {
        model: meta.clone(),
        rate: 3.0,
    }];
    let plan = alloc::hill_climb(&am, &tenants, hw.cpu_cores);
    println!(
        "plan @3 RPS: TPU prefix = {} of {} segments, {} CPU cores, predicted e2e {:.1} ms",
        plan.config.partitions[0],
        meta.partition_points,
        plan.config.cores[0],
        am.e2e_latency(&tenants, &plan.config, 0) * 1e3
    );

    // 3. Serve real requests through the PJRT runtime under that plan.
    let server = Server::start(
        &manifest,
        &[model.to_string()],
        CostModel::new(hw),
        plan.config,
        ServerOptions::default(),
    )
    .map_err(|e| e.to_string())?;

    let n_in: usize = meta.input_shape.iter().product();
    for i in 0..5 {
        let out = server
            .infer(0, vec![0.5; n_in])
            .map_err(|e| e.to_string())?;
        println!(
            "request {i}: {} logits, first = {:.4}, latency {:.1} ms",
            out.output.len(),
            out.output[0],
            out.latency_s * 1e3
        );
    }
    let stats = server.stats();
    println!(
        "done: {} requests, mean {:.1} ms",
        stats.completed,
        stats.per_model[0].mean() * 1e3
    );
    Ok(())
}
