//! Capacity planning with the analytic model (no serving involved):
//! for a given model mix, sweep the offered load through *admission
//! control* and print how the optimal configuration, predicted latency,
//! and processor utilizations evolve — the "what can this box sustain?"
//! question an operator asks before deployment. The saturation point is
//! exactly where `alloc::admit` starts refusing the mix, and the typed
//! `AdmissionError` reports the diverged objective it refused at.
//!
//! Runs on a fresh checkout (synthetic manifest fallback).
//!
//! ```bash
//! cargo run --release --example capacity_planning
//! ```

use swapless::alloc;
use swapless::analytic::{AnalyticModel, Tenant};
use swapless::config::HardwareSpec;
use swapless::model::Manifest;
use swapless::tpu::CostModel;

const MIX: [&str; 2] = ["efficientnet", "inceptionv4"];

fn main() -> Result<(), String> {
    let manifest = Manifest::load_or_synthetic("artifacts");
    let hw = HardwareSpec::default();
    let am = AnalyticModel::new(CostModel::new(hw.clone()));

    println!("capacity plan for mix {MIX:?} (equal request split)\n");
    println!(
        "{:>9}  {:<12} {:<10} {:>9} {:>9} {:>11} {:>10}",
        "total RPS", "partitions", "cores", "ρ(TPU)", "mean ms", "objective", "evals"
    );

    let mut saturation = None;
    for step in 1..=24 {
        let total = step as f64 * 0.5;
        let tenants: Vec<Tenant> = MIX
            .iter()
            .map(|n| {
                Ok(Tenant {
                    model: manifest.get(n)?.clone(),
                    rate: total / MIX.len() as f64,
                })
            })
            .collect::<Result<_, String>>()?;
        // The same admission decision the live `Server::attach` makes.
        match alloc::admit(&am, &tenants, hw.cpu_cores) {
            Ok(plan) => {
                let mean = am.mean_latency(&tenants, &plan.config);
                let rho = am.tpu_utilization(&tenants, &plan.config);
                println!(
                    "{:>9.1}  {:<12} {:<10} {:>9.2} {:>9.1} {:>11.4} {:>10}",
                    total,
                    format!("{:?}", plan.config.partitions),
                    format!("{:?}", plan.config.cores),
                    rho,
                    mean * 1e3,
                    plan.predicted_objective,
                    plan.evaluations
                );
            }
            Err(e) => {
                saturation = Some(total);
                println!(
                    "{total:>9.1}  -- admission refused: objective {} at ρ {:.2} --",
                    e.predicted_objective, e.tpu_utilization
                );
                break;
            }
        }
    }
    match saturation {
        Some(rate) => println!("\nsaturation: admission control refuses this mix at {rate:.1} RPS on this hardware."),
        None => println!("\nno saturation within the swept range."),
    }

    // What-if: double the SRAM (a hypothetical next-gen Edge TPU).
    let mut hw2 = hw.clone();
    hw2.sram_bytes *= 2;
    let am2 = AnalyticModel::new(CostModel::new(hw2));
    let tenants: Vec<Tenant> = MIX
        .iter()
        .map(|n| {
            Ok(Tenant {
                model: manifest.get(n)?.clone(),
                rate: 2.0,
            })
        })
        .collect::<Result<_, String>>()?;
    let base = alloc::hill_climb(&am, &tenants, hw.cpu_cores);
    let doubled = alloc::hill_climb(&am2, &tenants, hw.cpu_cores);
    println!(
        "\nwhat-if @4 RPS total: 8 MB SRAM -> {:.1} ms | 16 MB SRAM -> {:.1} ms",
        am.mean_latency(&tenants, &base.config) * 1e3,
        am2.mean_latency(&tenants, &doubled.config) * 1e3
    );

    // Backpressure planning: if the server runs a bounded queue (e.g.
    // `--queue-cap 8 --overload reject`), a rejection reports the wait a
    // newly admitted request would have faced — the station's predicted
    // service backlog over its servers. Size client retry budgets from it.
    let mean_service = am.mean_latency(&tenants, &base.config);
    for cap in [4usize, 8, 16] {
        println!(
            "queue-cap {cap:>2}: an Overloaded rejection implies >= {:.1} ms of backlog",
            am.station_wait_estimate(cap as f64 * mean_service, 1) * 1e3
        );
    }
    Ok(())
}
