//! Live dynamic adaptation with tenant churn (the Fig. 8 + churn scenario
//! at compressed timescale): two models served through the real stack
//! while the request mix shifts AND a third tenant attaches mid-run and
//! departs again. The online policy detects rate changes from its sliding
//! window and re-plans; attach/detach fire the same policy's lifecycle
//! hooks. Watch the config flips and admission decisions in the output.
//!
//! Runs on a fresh checkout (synthetic manifest + emulated backend).
//!
//! ```bash
//! cargo run --release --example dynamic_adaptation
//! ```

use std::time::{Duration, Instant};

use swapless::config::{HardwareSpec, RuntimeConfig};
use swapless::coordinator::{AttachError, AttachOptions, ServerBuilder};
use swapless::model::Manifest;
use swapless::tpu::CostModel;
use swapless::util::rng::Rng;

const MODELS: [&str; 2] = ["mnasnet", "squeezenet"];
/// Three phases of (mnasnet, squeezenet) RPS — squeezenet ramps up.
const PHASES: [(f64, f64); 3] = [(6.0, 1.0), (6.0, 8.0), (1.0, 12.0)];
const PHASE_S: f64 = 6.0;
/// The guest tenant attaches at the start of phase 1, departs at phase 2.
const GUEST: &str = "efficientnet";
const GUEST_RATE: f64 = 3.0;

fn main() -> Result<(), String> {
    let manifest = Manifest::load_or_synthetic("artifacts");
    let hw = HardwareSpec::default();
    let cost = CostModel::new(hw.clone());

    let server = ServerBuilder::new(&manifest, cost)
        .k_max(hw.cpu_cores)
        .adaptive(true)
        .runtime(RuntimeConfig {
            rate_window_s: 4.0,
            realloc_period_s: 1.0,
            realloc_threshold: 0.3,
        })
        .build()
        .map_err(|e| e.to_string())?;
    println!("backend: {:?}", server.backend());

    // Attach the two standing tenants through admission control.
    let mut handles = Vec::new();
    for (name, rate) in MODELS.iter().zip([PHASES[0].0, PHASES[0].1]) {
        let h = server
            .attach(name, AttachOptions { rate_hint: rate, ..Default::default() })
            .map_err(|e| e.to_string())?;
        handles.push(h);
    }
    let initial = server.current_config();
    println!(
        "initial plan: P={:?} K={:?}",
        initial.partitions, initial.cores
    );

    // Admission control in action: a tenant declaring an impossible rate
    // is refused with the predicted objective, without disturbing service.
    match server.attach(GUEST, AttachOptions { rate_hint: 1e6, ..Default::default() }) {
        Err(AttachError::Admission(e)) => println!(
            "admission: {GUEST} @ 1e6 rps refused (predicted objective {}, ρ {:.2})",
            e.predicted_objective, e.tpu_utilization
        ),
        other => println!("unexpected admission outcome: {other:?}"),
    }

    let mut rng = Rng::new(11);
    let t0 = Instant::now();
    let mut last_cfg = server.current_config();
    let mut pending = Vec::new();
    let mut guest: Option<swapless::analytic::TenantHandle> = None;
    for (phase, (r0, r1)) in PHASES.iter().enumerate() {
        println!("\n-- phase {phase}: rates = ({r0}, {r1}) rps --");
        // Churn: the guest joins for phase 1 only.
        if phase == 1 {
            let opts = AttachOptions {
                rate_hint: GUEST_RATE,
                ..Default::default()
            };
            match server.attach(GUEST, opts) {
                Ok(h) => {
                    println!("  attached {GUEST} as {h} @ {GUEST_RATE} rps");
                    guest = Some(h);
                }
                Err(e) => println!("  attach {GUEST} refused: {e}"),
            }
        }
        if phase == 2 {
            if let Some(h) = guest.take() {
                let st = server.detach(h).map_err(|e| e.to_string())?;
                println!(
                    "  detached {GUEST} ({h}): n={} mean {:.1} ms",
                    st.latency.count(),
                    st.latency.mean() * 1e3
                );
            }
        }
        let phase_end = (phase as f64 + 1.0) * PHASE_S;
        let rates = [*r0, *r1];
        let mut next_at = [
            t0.elapsed().as_secs_f64() + rng.exponential(rates[0]),
            t0.elapsed().as_secs_f64() + rng.exponential(rates[1]),
        ];
        let mut guest_next = guest
            .map(|_| t0.elapsed().as_secs_f64() + rng.exponential(GUEST_RATE));
        loop {
            let now = t0.elapsed().as_secs_f64();
            if now >= phase_end {
                break;
            }
            // Earliest due stream: one of the two standing tenants, or the guest.
            let m = if next_at[0] <= next_at[1] { 0 } else { 1 };
            let due_guest = guest_next.map(|t| t < next_at[m]).unwrap_or(false);
            let due_t = if due_guest { guest_next.unwrap() } else { next_at[m] };
            if due_t > phase_end {
                std::thread::sleep(Duration::from_secs_f64(
                    (phase_end - now).max(0.0).min(0.05),
                ));
                continue;
            }
            if due_t > now {
                std::thread::sleep(Duration::from_secs_f64(due_t - now));
            }
            if due_guest {
                let h = guest.unwrap();
                if let Some(meta) = server.model_meta(h) {
                    let n_in: usize = meta.input_shape.iter().product();
                    pending.push(server.submit(h, vec![0.5; n_in]));
                }
                guest_next = Some(due_t + rng.exponential(GUEST_RATE));
            } else {
                let h = handles[m];
                let meta = server.model_meta(h).expect("standing tenant");
                let n_in: usize = meta.input_shape.iter().product();
                pending.push(server.submit(h, vec![0.5; n_in]));
                next_at[m] += rng.exponential(rates[m]);
            }

            let cfg = server.current_config();
            if cfg != last_cfg {
                println!(
                    "  t={:.1}s reconfigured: P={:?} K={:?}",
                    t0.elapsed().as_secs_f64(),
                    cfg.partitions,
                    cfg.cores
                );
                last_cfg = cfg;
            }
        }
    }
    let mut clean_failures = 0usize;
    for ticket in pending {
        if ticket.wait().is_err() {
            clean_failures += 1;
        }
    }
    let stats = server.stats();
    println!(
        "\nserved {} requests total ({clean_failures} failed cleanly at churn)",
        stats.completed
    );
    for t in &stats.per_tenant {
        if t.latency.count() > 0 {
            println!(
                "  {:<12} {}{} n={:<5} mean {:>6.1} ms  p95 {:>6.1} ms",
                t.name,
                t.handle,
                if t.detached { " (detached)" } else { "" },
                t.latency.count(),
                t.latency.mean() * 1e3,
                t.latency.percentile(95.0) * 1e3
            );
        }
    }
    println!(
        "reconfigurations: {}; allocator decisions recorded: {} (max {:.0} µs)",
        stats.reconfigs,
        stats.decision_micros.len(),
        stats
            .decision_micros
            .iter()
            .fold(0.0f64, |a, b| a.max(*b))
    );
    Ok(())
}
