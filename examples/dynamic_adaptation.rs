//! Live dynamic adaptation (the Fig. 8 scenario at compressed timescale):
//! two models served through the real stack while the request mix shifts;
//! the online re-allocator detects the change from its sliding window and
//! re-partitions on the fly. Watch the config flips in the output.
//!
//! ```bash
//! cargo run --release --example dynamic_adaptation
//! ```

use std::time::{Duration, Instant};

use swapless::alloc;
use swapless::analytic::Tenant;
use swapless::config::{HardwareSpec, RuntimeConfig};
use swapless::coordinator::{Server, ServerOptions};
use swapless::model::Manifest;
use swapless::tpu::CostModel;
use swapless::util::rng::Rng;

const MODELS: [&str; 2] = ["mnasnet", "squeezenet"];
/// Three phases of (mnasnet, squeezenet) RPS — squeezenet ramps up.
const PHASES: [(f64, f64); 3] = [(6.0, 1.0), (6.0, 8.0), (1.0, 12.0)];
const PHASE_S: f64 = 6.0;

fn main() -> Result<(), String> {
    let manifest = Manifest::load("artifacts")?;
    let hw = HardwareSpec::default();
    let cost = CostModel::new(hw.clone());
    let am = swapless::analytic::AnalyticModel::new(cost.clone());
    let names: Vec<String> = MODELS.iter().map(|s| s.to_string()).collect();
    let tenants: Vec<Tenant> = MODELS
        .iter()
        .zip([PHASES[0].0, PHASES[0].1])
        .map(|(n, r)| {
            Ok(Tenant {
                model: manifest.get(n)?.clone(),
                rate: r,
            })
        })
        .collect::<Result<_, String>>()?;

    let initial = alloc::hill_climb(&am, &tenants, hw.cpu_cores).config;
    println!(
        "initial plan: P={:?} K={:?}",
        initial.partitions, initial.cores
    );

    let server = Server::start(
        &manifest,
        &names,
        cost,
        initial,
        ServerOptions {
            adaptive: true,
            runtime: RuntimeConfig {
                rate_window_s: 4.0,
                realloc_period_s: 1.0,
                realloc_threshold: 0.3,
            },
            ..Default::default()
        },
    )
    .map_err(|e| e.to_string())?;

    let mut rng = Rng::new(11);
    let t0 = Instant::now();
    let mut last_cfg = server.current_config();
    let mut pending = Vec::new();
    for (phase, (r0, r1)) in PHASES.iter().enumerate() {
        println!("\n-- phase {phase}: rates = ({r0}, {r1}) rps --");
        let phase_end = (phase as f64 + 1.0) * PHASE_S;
        let rates = [*r0, *r1];
        let mut next_at = [
            t0.elapsed().as_secs_f64() + rng.exponential(rates[0]),
            t0.elapsed().as_secs_f64() + rng.exponential(rates[1]),
        ];
        loop {
            let now = t0.elapsed().as_secs_f64();
            if now >= phase_end {
                break;
            }
            let m = if next_at[0] <= next_at[1] { 0 } else { 1 };
            if next_at[m] > phase_end {
                std::thread::sleep(Duration::from_secs_f64(
                    (phase_end - now).max(0.0).min(0.05),
                ));
                continue;
            }
            if next_at[m] > now {
                std::thread::sleep(Duration::from_secs_f64(next_at[m] - now));
            }
            let n_in: usize = server.tenants()[m].model.input_shape.iter().product();
            pending.push(server.submit(m, vec![0.5; n_in]));
            next_at[m] += rng.exponential(rates[m]);

            let cfg = server.current_config();
            if cfg != last_cfg {
                println!(
                    "  t={:.1}s reconfigured: P={:?} K={:?}",
                    t0.elapsed().as_secs_f64(),
                    cfg.partitions,
                    cfg.cores
                );
                last_cfg = cfg;
            }
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let stats = server.stats();
    println!("\nserved {} requests total", stats.completed);
    for (i, h) in stats.per_model.iter().enumerate() {
        if h.count() > 0 {
            println!(
                "  {:<12} n={:<5} mean {:>6.1} ms  p95 {:>6.1} ms",
                MODELS[i],
                h.count(),
                h.mean() * 1e3,
                h.percentile(95.0) * 1e3
            );
        }
    }
    println!(
        "reconfigurations: {}; allocator decisions recorded: {} (max {:.0} µs)",
        stats.reconfigs,
        stats.decision_micros.len(),
        stats
            .decision_micros
            .iter()
            .fold(0.0f64, |a, b| a.max(*b))
    );
    Ok(())
}
